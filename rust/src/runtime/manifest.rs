//! Artifact manifest parsing (`artifacts/manifest.json`).
//!
//! The manifest is the contract between the python AOT path and the rust
//! runtime: every executable's argument schema (weights vs runtime inputs,
//! per-block weight indirection for the shared attn/mlp stage executables)
//! and every weight blob's shape + file.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One argument of an executable, in positional order.
#[derive(Clone, Debug, PartialEq)]
pub enum ArgSpec {
    /// Fixed weight blob (global weight id).
    Weight(usize),
    /// Per-block weight: resolved via `ExeSpec::block_weights[field][block]`.
    BlockWeight(String),
    /// Runtime input tensor.
    Input { name: String, shape: Vec<usize> },
}

/// One compiled executable (a "stage" the coordinator maps to an acc).
#[derive(Clone, Debug)]
pub struct ExeSpec {
    pub name: String,
    pub hlo: PathBuf,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<Vec<usize>>,
    pub model: Option<String>,
    pub stage: Option<String>,
    pub batch: Option<usize>,
    /// field -> weight id per block (length = depth) for BlockWeight args.
    pub block_weights: BTreeMap<String, Vec<usize>>,
}

/// One weight blob on disk.
#[derive(Clone, Debug)]
pub struct WeightSpec {
    pub id: usize,
    pub name: String,
    pub shape: Vec<usize>,
    pub file: PathBuf,
}

/// Model metadata recorded by the AOT step.
#[derive(Clone, Debug, Default)]
pub struct ModelInfo {
    pub embed_dim: usize,
    pub num_heads: usize,
    pub depth: usize,
    pub tokens: usize,
    pub img_size: usize,
    pub num_classes: usize,
    pub macs_per_image: u64,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub executables: Vec<ExeSpec>,
    pub weights: Vec<WeightSpec>,
    pub models: BTreeMap<String, ModelInfo>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let mut weights = Vec::new();
        for w in j.get("weights").and_then(|x| x.as_arr()).unwrap_or(&[]) {
            weights.push(WeightSpec {
                id: w.get("id").and_then(Json::as_usize).context("weight id")?,
                name: w
                    .get("name")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
                shape: shape_of(w.get("shape").context("weight shape")?)?,
                file: dir.join(w.get("file").and_then(Json::as_str).context("file")?),
            });
        }
        // ids must be dense and ordered (the store indexes by id)
        for (i, w) in weights.iter().enumerate() {
            if w.id != i {
                bail!("weight ids not dense at {i}");
            }
        }

        let mut executables = Vec::new();
        for e in j.get("executables").and_then(|x| x.as_arr()).unwrap_or(&[]) {
            let mut args = Vec::new();
            for a in e.get("args").and_then(|x| x.as_arr()).unwrap_or(&[]) {
                let kind = a.get("kind").and_then(Json::as_str).context("arg kind")?;
                args.push(match kind {
                    "weight" => {
                        ArgSpec::Weight(a.get("weight").and_then(Json::as_usize).context("weight ref")?)
                    }
                    "block_weight" => ArgSpec::BlockWeight(
                        a.get("field").and_then(Json::as_str).context("field")?.to_string(),
                    ),
                    "input" => ArgSpec::Input {
                        name: a
                            .get("name")
                            .and_then(Json::as_str)
                            .unwrap_or("input")
                            .to_string(),
                        shape: shape_of(a.get("shape").context("input shape")?)?,
                    },
                    other => bail!("unknown arg kind {other}"),
                });
            }
            let mut block_weights = BTreeMap::new();
            if let Some(bw) = e.get("block_weights").and_then(Json::as_obj) {
                for (field, ids) in bw {
                    let ids: Result<Vec<usize>> = ids
                        .as_arr()
                        .context("block weight ids")?
                        .iter()
                        .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad id")))
                        .collect();
                    block_weights.insert(field.clone(), ids?);
                }
            }
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(shape_of)
                .collect::<Result<Vec<_>>>()?;
            executables.push(ExeSpec {
                name: e.get("name").and_then(Json::as_str).context("exe name")?.to_string(),
                hlo: dir.join(e.get("hlo").and_then(Json::as_str).context("hlo path")?),
                args,
                outputs,
                model: e.get("model").and_then(Json::as_str).map(String::from),
                stage: e.get("stage").and_then(Json::as_str).map(String::from),
                batch: e.get("batch").and_then(Json::as_usize),
                block_weights,
            });
        }

        let mut models = BTreeMap::new();
        if let Some(ms) = j.get("models").and_then(Json::as_obj) {
            for (name, m) in ms {
                models.insert(
                    name.clone(),
                    ModelInfo {
                        embed_dim: m.get("embed_dim").and_then(Json::as_usize).unwrap_or(0),
                        num_heads: m.get("num_heads").and_then(Json::as_usize).unwrap_or(0),
                        depth: m.get("depth").and_then(Json::as_usize).unwrap_or(0),
                        tokens: m.get("tokens").and_then(Json::as_usize).unwrap_or(0),
                        img_size: m.get("img_size").and_then(Json::as_usize).unwrap_or(0),
                        num_classes: m.get("num_classes").and_then(Json::as_usize).unwrap_or(0),
                        macs_per_image: m
                            .get("macs_per_image")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0) as u64,
                    },
                );
            }
        }

        Ok(Manifest { dir: dir.to_path_buf(), executables, weights, models })
    }

    pub fn find(&self, name: &str) -> Result<&ExeSpec> {
        self.executables
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("executable '{name}' not in manifest"))
    }

    /// Whether a stage executable exists for (model, stage, batch).
    pub fn has_stage(&self, model: &str, stage: &str, batch: usize) -> bool {
        self.find_stage(model, stage, batch).is_ok()
    }

    /// Whether the manifest carries the full set of class-granular stage
    /// executables (qkv/bmm0/bmm1/proj/fc1/fc2 alongside embed/head) for
    /// (model, batch) — the prerequisite for serving an 8-class
    /// `ExecutionPlan` without coarsening.
    pub fn has_class_stages(&self, model: &str, batch: usize) -> bool {
        ["embed", "qkv", "bmm0", "bmm1", "proj", "fc1", "fc2", "head"]
            .iter()
            .all(|s| self.has_stage(model, s, batch))
    }

    /// Stage executable for (model, stage, batch).
    pub fn find_stage(&self, model: &str, stage: &str, batch: usize) -> Result<&ExeSpec> {
        self.executables
            .iter()
            .find(|e| {
                e.model.as_deref() == Some(model)
                    && e.stage.as_deref() == Some(stage)
                    && e.batch == Some(batch)
            })
            .ok_or_else(|| {
                anyhow!("no executable for model={model} stage={stage} batch={batch}")
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arts() -> PathBuf {
        // tests run from the crate root
        PathBuf::from("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(&arts()).expect("run `make artifacts` first");
        assert!(m.executables.len() >= 10);
        assert!(m.weights.len() > 100);
        assert!(m.models.contains_key("deit_t"));
        let info = &m.models["deit_t"];
        assert_eq!(info.embed_dim, 192);
        assert_eq!(info.tokens, 197);
    }

    #[test]
    fn smoke_executables_have_two_inputs() {
        let m = Manifest::load(&arts()).unwrap();
        for name in ["smoke", "smoke_pallas"] {
            let e = m.find(name).unwrap();
            assert_eq!(e.args.len(), 2);
            assert!(matches!(e.args[0], ArgSpec::Input { .. }));
        }
    }

    #[test]
    fn full_model_arg_schema() {
        let m = Manifest::load(&arts()).unwrap();
        let e = m.find("deit_t_full_b1").unwrap();
        // 152 weights + 1 input
        let inputs: Vec<_> = e
            .args
            .iter()
            .filter(|a| matches!(a, ArgSpec::Input { .. }))
            .collect();
        assert_eq!(inputs.len(), 1);
        if let ArgSpec::Input { shape, .. } = inputs[0] {
            assert_eq!(shape, &vec![1, 224, 224, 3]);
        }
        assert_eq!(e.outputs, vec![vec![1, 1000]]);
    }

    #[test]
    fn attn_stage_has_block_weights() {
        let m = Manifest::load(&arts()).unwrap();
        let e = m.find_stage("deit_t", "attn", 1).unwrap();
        assert!(!e.block_weights.is_empty());
        for ids in e.block_weights.values() {
            assert_eq!(ids.len(), 12); // one per block
        }
    }

    #[test]
    fn weight_files_exist() {
        let m = Manifest::load(&arts()).unwrap();
        for w in m.weights.iter().take(5) {
            assert!(w.file.exists(), "{}", w.file.display());
        }
    }

    #[test]
    fn missing_executable_errors() {
        let m = Manifest::load(&arts()).unwrap();
        assert!(m.find("nope").is_err());
        assert!(m.find_stage("deit_t", "attn", 99).is_err());
    }
}
