//! SSR design-space exploration (paper Sec. 4.4, Algorithms 1 & 2).
//!
//! Two coupled levels:
//!
//! * **Layer→Acc** ([`ea`]): which layer classes share which accelerator —
//!   searched with an evolutionary algorithm (Algorithm 1). The genome is an
//!   8-vector mapping each [`crate::graph::LayerClass`] to an accelerator id;
//!   `nacc = 1` is the sequential design, `nacc = 8` the fully spatial one,
//!   everything between is hybrid.
//! * **Acc-Customization** ([`acc_dse`]): per-accelerator
//!   `config_vector (h1,w1,w2,A,B,C,Part_A,Part_B,Part_C)` — exhaustive
//!   search (Algorithm 2) with the inter-acc-aware force-partition pruning
//!   of Fig. 8.
//!
//! [`eval`] ties them together (`SSR_DSE` in the paper's pseudocode):
//! partition resources ([`partition`]), customize each acc, list-schedule
//! the graph, and produce latency/throughput/energy.

pub mod acc_dse;
pub mod enumerate;
pub mod ea;
pub mod eval;
pub mod pareto;
pub mod partition;

use crate::analytical::{AccConfig, Features};
use crate::graph::{LayerClass, ALL_CLASSES};

/// Layer→Acc assignment genome: `acc_of[class.index()]` is the accelerator
/// id running that class (ids dense in `0..nacc()`).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Assignment {
    pub acc_of: Vec<usize>,
}

impl Assignment {
    pub fn new(acc_of: Vec<usize>) -> Self {
        assert_eq!(acc_of.len(), ALL_CLASSES.len());
        let mut a = Assignment { acc_of };
        a.normalize();
        a
    }

    /// The paper's sequential design: one monolithic accelerator.
    pub fn sequential() -> Self {
        Assignment::new(vec![0; ALL_CLASSES.len()])
    }

    /// The paper's fully spatial design: one accelerator per layer class.
    pub fn spatial() -> Self {
        Assignment::new((0..ALL_CLASSES.len()).collect())
    }

    /// Relabel acc ids in order of first appearance (canonical form, so
    /// {0,0,1,1,...} and {1,1,0,0,...} dedup to the same genome).
    pub fn normalize(&mut self) {
        let mut map: Vec<Option<usize>> = vec![None; ALL_CLASSES.len()];
        let mut next = 0;
        for a in self.acc_of.iter_mut() {
            let m = &mut map[*a];
            if m.is_none() {
                *m = Some(next);
                next += 1;
            }
            *a = m.unwrap();
        }
    }

    pub fn nacc(&self) -> usize {
        self.acc_of.iter().copied().max().unwrap_or(0) + 1
    }

    pub fn acc_of(&self, class: LayerClass) -> usize {
        self.acc_of[class.index()]
    }

    /// Classes on accelerator `acc`.
    pub fn classes_on(&self, acc: usize) -> Vec<LayerClass> {
        ALL_CLASSES
            .iter()
            .copied()
            .filter(|c| self.acc_of(*c) == acc)
            .collect()
    }

    /// Does `acc` host more than one layer class? (Multi-class accs pay the
    /// reconfiguration overhead; single-class accs run as persistent
    /// dataflow engines.)
    pub fn is_multi_class(&self, acc: usize) -> bool {
        self.acc_of.iter().filter(|&&a| a == acc).count() > 1
    }

    /// Whether any attention class (BMM0/BMM1) lands on `acc` — then the
    /// acc needs HMM-type1 and weight pinning is off (paper Sec. 4.3 (1)).
    pub fn has_attention(&self, acc: usize) -> bool {
        self.classes_on(acc).iter().any(|c| c.is_attention())
    }
}

/// A fully customized design: assignment + per-acc configuration.
#[derive(Clone, Debug)]
pub struct Design {
    pub assignment: Assignment,
    pub configs: Vec<AccConfig>,
    /// HCE lanes per accelerator (PL side).
    pub hce_lanes: Vec<u64>,
    pub features: Features,
}

/// Evaluation of a design at a given batch size.
#[derive(Clone, Copy, Debug)]
pub struct Eval {
    pub batch: usize,
    /// End-to-end latency for the whole batch (seconds).
    pub latency_s: f64,
    /// Effective throughput (TOPS) = batch * ops_per_image / latency.
    pub tops: f64,
    /// Energy efficiency (GOPS/W).
    pub gops_per_w: f64,
}

impl Eval {
    /// Service rate in images per second: the whole batch completes in
    /// `latency_s`, so this is what an SLA-aware scheduler can sustain by
    /// back-to-back launches of this design point.
    pub fn imgs_per_s(&self) -> f64 {
        self.batch as f64 / self.latency_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_is_one_acc() {
        let a = Assignment::sequential();
        assert_eq!(a.nacc(), 1);
        assert!(a.is_multi_class(0));
    }

    #[test]
    fn spatial_is_eight_accs() {
        let a = Assignment::spatial();
        assert_eq!(a.nacc(), 8);
        for acc in 0..8 {
            assert!(!a.is_multi_class(acc));
            assert_eq!(a.classes_on(acc).len(), 1);
        }
    }

    #[test]
    fn normalize_canonicalizes() {
        let a = Assignment::new(vec![5, 5, 2, 2, 7, 7, 5, 2]);
        assert_eq!(a.acc_of, vec![0, 0, 1, 1, 2, 2, 0, 1]);
        assert_eq!(a.nacc(), 3);
    }

    #[test]
    fn attention_detection() {
        let a = Assignment::new(vec![0, 0, 1, 1, 0, 0, 0, 0]);
        assert!(a.has_attention(1));
        assert!(!a.has_attention(0));
    }

    #[test]
    fn imgs_per_s_is_batch_over_latency() {
        let e = Eval { batch: 6, latency_s: 0.58e-3, tops: 26.7, gops_per_w: 0.0 };
        assert!((e.imgs_per_s() - 6.0 / 0.58e-3).abs() < 1e-9);
    }

    #[test]
    fn classes_on_partitions_all() {
        let a = Assignment::new(vec![0, 1, 1, 2, 0, 1, 2, 0]);
        let total: usize = (0..a.nacc()).map(|i| a.classes_on(i).len()).sum();
        assert_eq!(total, 8);
    }
}
