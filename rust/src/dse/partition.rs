//! Resource pre-allocation across accelerators (Algorithm 1 lines 30-33).
//!
//! "While the number of AIE together with PLIO is proportional to the total
//! number of operations assigned to the accelerator, the memory budget is
//! assigned according to the memory allocation strategy."

use super::Assignment;
use crate::analytical::Calib;
use crate::arch::Platform;
use crate::graph::Graph;

/// Per-accelerator resource budget.
#[derive(Clone, Debug, PartialEq)]
pub struct AccBudget {
    pub aie: u64,
    pub plio: u64,
    pub dsp: u64,
    pub bram: u64,
    pub uram: u64,
}

/// Minimum on-chip memory (bytes) to hold an acc's weights + ping-pong
/// activation buffers (the paper's first-round memory allocation, lines
/// 30-31: "buffer both the activations and weights on-chip ... without
/// memory stall").
pub fn min_mem_bytes(graph: &Graph, assignment: &Assignment, acc: usize) -> u64 {
    let mut weights = 0u64;
    let mut act_peak = 0u64;
    for n in &graph.nodes {
        if assignment.acc_of(n.class) == acc {
            weights += n.weight_bytes;
            // double-buffered input + output tiles
            act_peak = act_peak.max(2 * (n.in_bytes + n.out_bytes));
        }
    }
    weights + act_peak
}

/// Split the platform's resources over the accelerators of `assignment`,
/// proportional to assigned MACs (AIE/PLIO) and HCE elements (DSP), with
/// floors so tiny accs stay realizable.
pub fn hw_partition(
    platform: &Platform,
    calib: &Calib,
    graph: &Graph,
    assignment: &Assignment,
) -> Vec<AccBudget> {
    let nacc = assignment.nacc();
    let mut macs = vec![0u64; nacc];
    let mut hce = vec![0u64; nacc];
    let mut mem = vec![0u64; nacc];
    for n in &graph.nodes {
        let a = assignment.acc_of(n.class);
        macs[a] += n.dims.macs();
        hce[a] += n.hce.iter().map(|h| h.elems).sum::<u64>();
    }
    for a in 0..nacc {
        mem[a] = min_mem_bytes(graph, assignment, a);
    }
    let tot_macs: u64 = macs.iter().sum::<u64>().max(1);
    let tot_hce: u64 = hce.iter().sum::<u64>().max(1);
    let tot_mem: u64 = mem.iter().sum::<u64>().max(1);

    // Leave a small AIE/PLIO margin for routing (paper reaches 394/400).
    let aie_pool = platform.aie_total - platform.aie_total / 50;
    let plio_pool = platform.plio_total;
    // PL fabric is shared with the HCE engines and the AXI DMA (Table 8):
    // keep ~10% DSP headroom.
    let dsp_pool = platform.dsp_total * 9 / 10;
    let bram_pool = platform.bram_total;
    let uram_pool = platform.uram_total;
    let _ = calib;

    let mut budgets: Vec<AccBudget> = (0..nacc)
        .map(|a| AccBudget {
            aie: (aie_pool * macs[a] / tot_macs).max(4),
            plio: (plio_pool * macs[a] / tot_macs).max(4),
            dsp: (dsp_pool * hce[a] / tot_hce).max(32),
            bram: (bram_pool * mem[a] / tot_mem).max(64),
            uram: uram_pool * mem[a] / tot_mem,
        })
        .collect();

    // Clamp rounding overshoot: scale down if floors pushed totals over.
    for (field, pool, floor) in [
        (0usize, aie_pool, 2),
        (1, plio_pool, 2),
        (2, dsp_pool, 2),
        (3, bram_pool, 24),
    ] {
        let total: u64 = budgets
            .iter()
            .map(|b| match field {
                0 => b.aie,
                1 => b.plio,
                2 => b.dsp,
                _ => b.bram,
            })
            .sum();
        if total > pool {
            for b in budgets.iter_mut() {
                let v = match field {
                    0 => &mut b.aie,
                    1 => &mut b.plio,
                    2 => &mut b.dsp,
                    _ => &mut b.bram,
                };
                *v = (*v * pool / total).max(floor);
            }
        }
    }
    budgets
}

/// Rebalance AIE/PLIO across accelerators proportional to measured busy
/// time (stage equalization): accs that dominate the pipeline get more
/// array and stream resources. DSP/RAM budgets are kept. This is the
/// feedback loop the paper's coupled Layer→Acc / Acc-Customization DSE
/// realizes across EA generations, folded into one deterministic pass.
pub fn rebalance(
    platform: &Platform,
    prev: &[AccBudget],
    busy_s: &[f64],
) -> Vec<AccBudget> {
    assert_eq!(prev.len(), busy_s.len());
    let aie_pool = platform.aie_total - platform.aie_total / 50;
    let plio_pool = platform.plio_total;
    // Work-proportional damped update: an acc's "work" is its busy time
    // times its current allocation (aie-seconds). Allocating proportional
    // to work equalizes busy under an inverse-linear speedup model and
    // converges instead of oscillating.
    let work: Vec<f64> = prev
        .iter()
        .zip(busy_s)
        .map(|(b, &t)| (b.aie as f64 * t).max(1e-12))
        .collect();
    let plio_work: Vec<f64> = prev
        .iter()
        .zip(busy_s)
        .map(|(b, &t)| (b.plio as f64 * t).max(1e-12))
        .collect();
    let tot_work: f64 = work.iter().sum();
    let tot_pwork: f64 = plio_work.iter().sum();
    let mut out: Vec<AccBudget> = prev
        .iter()
        .enumerate()
        .map(|(i, b)| AccBudget {
            aie: ((aie_pool as f64 * work[i] / tot_work) as u64).max(4),
            plio: ((plio_pool as f64 * plio_work[i] / tot_pwork) as u64).max(4),
            ..b.clone()
        })
        .collect();
    for (aie_mode, pool) in [(true, aie_pool), (false, plio_pool)] {
        let total: u64 = out.iter().map(|b| if aie_mode { b.aie } else { b.plio }).sum();
        if total > pool {
            for b in out.iter_mut() {
                let v = if aie_mode { &mut b.aie } else { &mut b.plio };
                *v = (*v * pool / total).max(2);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck190;
    use crate::graph::{vit_graph, DEIT_T};

    #[test]
    fn sequential_gets_everything() {
        let p = vck190();
        let g = vit_graph(&DEIT_T);
        let b = hw_partition(&p, &Calib::default(), &g, &Assignment::sequential());
        assert_eq!(b.len(), 1);
        assert!(b[0].aie >= 380, "aie={}", b[0].aie);
        assert!(b[0].aie <= p.aie_total);
    }

    #[test]
    fn spatial_splits_proportionally() {
        let p = vck190();
        let g = vit_graph(&DEIT_T);
        let b = hw_partition(&p, &Calib::default(), &g, &Assignment::spatial());
        assert_eq!(b.len(), 8);
        let total: u64 = b.iter().map(|x| x.aie).sum();
        assert!(total <= p.aie_total, "total AIE {total}");
        // FC1/FC2 (big MMs) should out-budget Head (1 x d x 1000 once).
        let fc1 = &b[crate::graph::LayerClass::Fc1.index()];
        let head = &b[crate::graph::LayerClass::Head.index()];
        assert!(fc1.aie > head.aie);
    }

    #[test]
    fn budgets_respect_pools() {
        let p = vck190();
        let g = vit_graph(&DEIT_T);
        for assignment in [
            Assignment::sequential(),
            Assignment::spatial(),
            Assignment::new(vec![0, 1, 1, 1, 0, 2, 2, 0]),
        ] {
            let b = hw_partition(&p, &Calib::default(), &g, &assignment);
            assert!(b.iter().map(|x| x.aie).sum::<u64>() <= p.aie_total);
            assert!(b.iter().map(|x| x.plio).sum::<u64>() <= p.plio_total);
            assert!(b.iter().map(|x| x.dsp).sum::<u64>() <= p.dsp_total);
        }
    }

    #[test]
    fn min_mem_counts_weights_once() {
        let g = vit_graph(&DEIT_T);
        let a = Assignment::sequential();
        let m = min_mem_bytes(&g, &a, 0);
        let weights: u64 = g.nodes.iter().map(|n| n.weight_bytes).sum();
        assert!(m >= weights);
        assert!(m < weights + 10 * 1024 * 1024);
    }
}
