//! Latency-throughput Pareto front utilities (Fig. 2).
//!
//! A point dominates another if it has <= latency AND >= throughput (with
//! at least one strict). The front is what the paper plots for the
//! sequential trendline, the spatial trendline, and the SSR-hybrid points.

/// One design point on the latency/throughput plane.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Point {
    pub latency_ms: f64,
    pub tops: f64,
    /// Provenance tag (batch, nacc) for reporting.
    pub batch: usize,
    pub nacc: usize,
}

impl Point {
    pub fn dominates(&self, other: &Point) -> bool {
        self.latency_ms <= other.latency_ms
            && self.tops >= other.tops
            && (self.latency_ms < other.latency_ms || self.tops > other.tops)
    }
}

/// Extract the non-dominated subset, sorted by latency ascending.
pub fn pareto_front(points: &[Point]) -> Vec<Point> {
    let mut front: Vec<Point> = points
        .iter()
        .filter(|p| !points.iter().any(|q| q.dominates(p)))
        .copied()
        .collect();
    // total_cmp, not partial_cmp().unwrap(): a NaN latency/tops from a
    // degenerate eval must not panic the pruning (NaN sorts last and, by
    // IEEE comparison semantics, never dominates or is dominated).
    front.sort_by(|a, b| {
        a.latency_ms
            .total_cmp(&b.latency_ms)
            .then(b.tops.total_cmp(&a.tops))
    });
    front.dedup_by(|a, b| a.latency_ms == b.latency_ms && a.tops == b.tops);
    front
}

/// Indices of the non-dominated subset, sorted by latency ascending (ties:
/// higher throughput first). Keeps provenance: callers that carry richer
/// records per point (e.g. a serializable plan front) can prune without
/// losing the mapping back to their own data.
pub fn pareto_indices(points: &[Point]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len())
        .filter(|&i| !points.iter().any(|q| q.dominates(&points[i])))
        .collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .latency_ms
            .total_cmp(&points[b].latency_ms)
            .then(points[b].tops.total_cmp(&points[a].tops))
    });
    idx.dedup_by(|&mut a, &mut b| {
        points[a].latency_ms == points[b].latency_ms && points[a].tops == points[b].tops
    });
    idx
}

/// Best throughput meeting a latency constraint (Table 6 cells); None = "x".
pub fn best_under(points: &[Point], lat_cons_ms: f64) -> Option<Point> {
    // NaN tops is excluded outright: total_cmp orders NaN above +inf, so a
    // bare max_by would crown a degenerate point "best".
    points
        .iter()
        .filter(|p| p.latency_ms <= lat_cons_ms && !p.tops.is_nan())
        .max_by(|a, b| a.tops.total_cmp(&b.tops))
        .copied()
}

/// Does front `a` weakly dominate front `b` everywhere (the paper's "better
/// Pareto front" claim)? For every point in `b` there is a point in `a`
/// with <= latency and >= tops.
pub fn front_dominates(a: &[Point], b: &[Point]) -> bool {
    b.iter().all(|q| {
        a.iter()
            .any(|p| p.latency_ms <= q.latency_ms && p.tops >= q.tops)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(l: f64, t: f64) -> Point {
        Point { latency_ms: l, tops: t, batch: 1, nacc: 1 }
    }

    #[test]
    fn domination_strictness() {
        assert!(pt(1.0, 10.0).dominates(&pt(2.0, 5.0)));
        assert!(!pt(1.0, 10.0).dominates(&pt(1.0, 10.0))); // equal: no
        assert!(!pt(1.0, 5.0).dominates(&pt(2.0, 10.0))); // tradeoff: no
    }

    #[test]
    fn front_filters_dominated() {
        let pts = [pt(1.0, 10.0), pt(2.0, 5.0), pt(0.5, 3.0), pt(3.0, 12.0)];
        let f = pareto_front(&pts);
        // (2.0, 5) dominated by (1.0, 10); others survive
        assert_eq!(f.len(), 3);
        assert!(f.iter().all(|p| p.latency_ms != 2.0));
        // sorted by latency
        assert!(f.windows(2).all(|w| w[0].latency_ms <= w[1].latency_ms));
    }

    #[test]
    fn best_under_matches_table6_semantics() {
        let pts = [pt(0.22, 10.9), pt(1.3, 11.17), pt(0.58, 26.7), pt(0.43, 18.56)];
        assert_eq!(best_under(&pts, 2.0).unwrap().tops, 26.7);
        assert_eq!(best_under(&pts, 0.5).unwrap().tops, 18.56);
        assert_eq!(best_under(&pts, 0.4).unwrap().tops, 10.9);
        assert!(best_under(&pts, 0.1).is_none()); // the "x" cells
    }

    #[test]
    fn front_domination() {
        let hybrid = [pt(0.22, 10.9), pt(0.43, 18.56), pt(0.58, 26.7)];
        let seq = [pt(0.22, 10.9), pt(1.3, 11.17)];
        assert!(front_dominates(&hybrid, &seq));
        assert!(!front_dominates(&seq, &hybrid));
    }

    #[test]
    fn indices_match_front_and_keep_provenance() {
        let pts = [pt(1.0, 10.0), pt(2.0, 5.0), pt(0.5, 3.0), pt(3.0, 12.0)];
        let idx = pareto_indices(&pts);
        let via_idx: Vec<Point> = idx.iter().map(|&i| pts[i]).collect();
        assert_eq!(via_idx, pareto_front(&pts));
        assert_eq!(idx, vec![2, 0, 3]); // sorted by latency, (2.0, 5) dominated
    }

    #[test]
    fn nan_points_do_not_panic_the_pruning() {
        // A degenerate eval can leak NaN latency/tops; pruning and sorting
        // must survive it (NaN compares false to everything, so it neither
        // dominates nor is dominated, and total_cmp sorts it last).
        let pts = [
            pt(1.0, 10.0),
            pt(f64::NAN, 5.0),
            pt(2.0, f64::NAN),
            pt(0.5, 3.0),
        ];
        let f = pareto_front(&pts);
        let idx = pareto_indices(&pts);
        assert_eq!(f.len(), idx.len());
        // the finite non-dominated points are still present and ordered
        let finite: Vec<&Point> =
            f.iter().filter(|p| p.latency_ms.is_finite() && p.tops.is_finite()).collect();
        assert_eq!(finite.len(), 2);
        assert!(finite[0].latency_ms <= finite[1].latency_ms);
        assert_eq!(best_under(&pts, 3.0).unwrap().tops, 10.0);
    }

    #[test]
    fn empty_inputs() {
        assert!(pareto_front(&[]).is_empty());
        assert!(best_under(&[], 1.0).is_none());
        assert!(front_dominates(&[], &[])); // vacuous
    }
}
