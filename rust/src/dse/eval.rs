//! `SSR_DSE` (paper Algorithm 1 lines 27-37): evaluate a Layer→Acc
//! assignment end to end — partition resources, customize accelerators,
//! derive per-node costs, and produce the closed-form latency/throughput
//! estimate the EA optimizes. The event-driven simulator (`crate::sim`)
//! replays the same per-node costs with explicit resource contention and is
//! the "on-board measurement" analog in Table 7.

use super::acc_dse::{customize_all, AccChoice};
use super::partition::{hw_partition, AccBudget};
use super::{Assignment, Design, Eval};
use crate::analytical::comm::{classify, comm_time, CommPath};
use crate::analytical::hce::{exposed_hce, lanes_for_dsp};
use crate::analytical::hmm::mm_time;
use crate::analytical::{energy, Calib, Features};
use crate::arch::Platform;
use crate::graph::Graph;
use crate::plan::ExecutionPlan;

/// Per-node cost breakdown (per image).
#[derive(Clone, Debug)]
pub struct NodeCost {
    pub acc: usize,
    /// MM/BMM seconds on the AIE array.
    pub mm_s: f64,
    /// Exposed (non-overlapped) HCE seconds.
    pub hce_s: f64,
    /// Launch/reconfiguration overhead seconds.
    pub overhead_s: f64,
    /// Exposed inter-acc communication seconds paid before this node
    /// (summed over incoming edges), plus the path class for the sim.
    pub comm_in_s: f64,
    pub comm_paths: Vec<(usize, CommPath, u64)>, // (producer node, path, bytes)
}

impl NodeCost {
    /// Seconds the accelerator is occupied by this node.
    pub fn busy_s(&self) -> f64 {
        self.mm_s + self.hce_s + self.overhead_s
    }
}

/// Search-cost accounting for Fig. 10.
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchStats {
    pub configs_evaluated: usize,
    pub configs_pruned: usize,
}

/// A fully evaluated design: per-node costs + derived aggregates, plus the
/// materialized [`ExecutionPlan`] — the DSE result is a directly executable
/// artifact, not just a score.
#[derive(Clone, Debug)]
pub struct Evaluated {
    pub design: Design,
    pub budgets: Vec<AccBudget>,
    pub node_costs: Vec<NodeCost>,
    pub stats: SearchStats,
    /// Class-granular execution plan (micro-batch 1); re-target other
    /// micro-batch variants with [`ExecutionPlan::with_micro_batch`].
    pub plan: ExecutionPlan,
}

/// Build and cost a design for `assignment` (None if no feasible config).
pub fn build_design(
    platform: &Platform,
    calib: &Calib,
    graph: &Graph,
    assignment: &Assignment,
    features: Features,
    inter_acc_aware: bool,
) -> Option<Evaluated> {
    let mut budgets = hw_partition(platform, calib, graph, assignment);
    let mut choices: Vec<AccChoice> =
        customize_all(platform, calib, graph, assignment, &budgets, inter_acc_aware)?;
    // Stage-equalizing rebalance: reallocate AIE/PLIO toward accelerators
    // that dominate per-image busy time (work-proportional damped update),
    // keeping a round only if it reduces the bottleneck stage.
    if assignment.nacc() > 1 {
        for _ in 0..3 {
            let busy: Vec<f64> = choices
                .iter()
                .map(|c| c.mm_seconds.iter().sum::<f64>())
                .collect();
            let old_max = busy.iter().cloned().fold(0.0f64, f64::max);
            let new_budgets = super::partition::rebalance(platform, &budgets, &busy);
            if new_budgets == budgets {
                break;
            }
            let Some(new_choices) =
                customize_all(platform, calib, graph, assignment, &new_budgets, inter_acc_aware)
            else {
                break;
            };
            let new_max = new_choices
                .iter()
                .map(|c| c.mm_seconds.iter().sum::<f64>())
                .fold(0.0f64, f64::max);
            if new_max >= old_max {
                break; // keep the previous (better) allocation
            }
            budgets = new_budgets;
            choices = new_choices;
        }
    }
    let stats = SearchStats {
        configs_evaluated: choices.iter().map(|c| c.evaluated).sum(),
        configs_pruned: choices.iter().map(|c| c.pruned).sum(),
    };
    let hce_lanes: Vec<u64> =
        budgets.iter().map(|b| lanes_for_dsp(calib, b.dsp)).collect();
    let configs: Vec<_> = choices.iter().map(|c| c.config).collect();

    let design = Design {
        assignment: assignment.clone(),
        configs: configs.clone(),
        hce_lanes: hce_lanes.clone(),
        features,
    };

    // Per-node costs.
    let mut node_costs = Vec::with_capacity(graph.nodes.len());
    for n in &graph.nodes {
        let acc = assignment.acc_of(n.class);
        let cfg = &configs[acc];
        // Weight pinning (HMM-type0) only if the node has weights AND the
        // acc hosts no attention class (paper Sec. 4.3 (1): the optimizable
        // flag is per Layer→Acc assignment).
        let pinned = n.weight_bytes > 0 && !assignment.has_attention(acc);
        let mm = mm_time(platform, calib, cfg, &n.dims, pinned);
        let hce = exposed_hce(
            platform,
            calib,
            &n.hce,
            hce_lanes[acc],
            mm.seconds,
            features.fine_grained_pipeline,
        );
        let overhead = if assignment.is_multi_class(acc) {
            calib.reconfig_us * 1e-6
        } else {
            calib.persist_us * 1e-6
        };
        let mut comm_in_s = 0.0;
        let mut comm_paths = Vec::new();
        for &d in &n.deps {
            let prod = &graph.nodes[d];
            let pacc = assignment.acc_of(prod.class);
            let path = classify(
                features.on_chip_forwarding,
                pacc == acc,
                &configs[pacc],
                cfg,
                inter_acc_aware,
            );
            let t = comm_time(platform, calib, path, prod.out_bytes);
            comm_in_s += t;
            comm_paths.push((d, path, prod.out_bytes));
        }
        node_costs.push(NodeCost {
            acc,
            mm_s: mm.seconds,
            hce_s: hce,
            overhead_s: overhead,
            comm_in_s,
            comm_paths,
        });
    }

    let plan = ExecutionPlan::from_graph(graph, assignment, 1);
    Some(Evaluated { design, budgets, node_costs, stats, plan })
}

impl Evaluated {
    /// The execution plan re-targeted at a runtime micro-batch variant
    /// (`bN` stage executables).
    pub fn plan_at(&self, micro_batch: usize) -> ExecutionPlan {
        self.plan.clone().with_micro_batch(micro_batch)
    }

    /// Per-image serial time on each accelerator (pipeline stage weight).
    pub fn acc_busy_per_image(&self) -> Vec<f64> {
        let nacc = self.design.assignment.nacc();
        let mut busy = vec![0.0; nacc];
        for c in &self.node_costs {
            busy[c.acc] += c.busy_s();
        }
        busy
    }

    /// Chain (critical-path) time for one image through all nodes.
    pub fn chain_s(&self) -> f64 {
        self.node_costs.iter().map(|c| c.busy_s() + c.comm_in_s).sum()
    }

    /// Per-image DDR time (serialized global resource when forwarding off).
    pub fn ddr_per_image_s(&self, platform: &Platform) -> f64 {
        let calib = Calib::default();
        self.node_costs
            .iter()
            .flat_map(|c| &c.comm_paths)
            .filter(|(_, p, _)| *p == CommPath::Ddr)
            .map(|(_, _, b)| crate::analytical::comm::ddr_seconds(platform, &calib, *b))
            .sum()
    }

    /// Analytical evaluation at `batch`: a one-pass greedy list schedule
    /// over (node, batch) instances — exactly the paper's Algorithm 1
    /// lines 28-29 ("assign a layer to the pipeline as soon as its
    /// accelerator is available and its dependencies are resolved") — with
    /// per-edge exposed comm folded into readiness. Unlike the simulator it
    /// models no DDR-link contention and no cross-batch reordering, which
    /// is what Table 7 measures the residual of. Additionally lower-bounded
    /// by the serialized per-image DDR traffic when forwarding is off.
    pub fn evaluate(&self, platform: &Platform, graph: &Graph, batch: usize) -> Eval {
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;

        let n = graph.nodes.len();
        let nt = n * batch;
        let nacc = self.design.assignment.nacc();

        // Dependency counts: same-image graph deps + same-node previous
        // batch (stream order through the shared executable/acc state).
        let mut pending = vec![0u32; nt];
        let mut ready_time = vec![0.0f64; nt];
        for b in 0..batch {
            for (i, node) in graph.nodes.iter().enumerate() {
                let t = b * n + i;
                pending[t] = node.deps.len() as u32 + u32::from(b > 0);
            }
        }

        // Per-acc queue of ready tasks, ordered by readiness (a streaming
        // accelerator consumes whatever arrives first).
        let mut acc_queue: Vec<BinaryHeap<Reverse<(u64, usize)>>> =
            (0..nacc).map(|_| BinaryHeap::new()).collect();
        let mut acc_busy_task: Vec<Option<usize>> = vec![None; nacc];
        // Global completion events.
        let mut events: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        let key = |s: f64| (s * 1e15) as u64; // stable ordering key

        let push_ready = |t: usize,
                          ready_time: &[f64],
                          acc_queue: &mut Vec<BinaryHeap<Reverse<(u64, usize)>>>| {
            let acc = self.node_costs[t % n].acc;
            acc_queue[acc].push(Reverse((key(ready_time[t]), t)));
        };
        let mut makespan = 0.0f64;
        let mut now = 0.0f64;

        for b in 0..batch {
            for i in 0..n {
                let t = b * n + i;
                if pending[t] == 0 {
                    push_ready(t, &ready_time, &mut acc_queue);
                }
            }
        }
        loop {
            // Start tasks on every idle acc with a non-empty queue.
            for acc in 0..nacc {
                if acc_busy_task[acc].is_none() {
                    if let Some(Reverse((_, t))) = acc_queue[acc].pop() {
                        let cost = &self.node_costs[t % n];
                        let start = ready_time[t].max(now);
                        let end = start + cost.busy_s();
                        acc_busy_task[acc] = Some(t);
                        events.push(Reverse((key(end), t)));
                    }
                }
            }
            let Some(Reverse((ek, t))) = events.pop() else { break };
            let end = ek as f64 / 1e15;
            now = end;
            makespan = makespan.max(end);
            let acc = self.node_costs[t % n].acc;
            acc_busy_task[acc] = None;
            // Release dependents.
            let b = t / n;
            let i = t % n;
            let release = |dep_t: usize,
                               extra_comm: f64,
                               pending: &mut [u32],
                               ready_time: &mut [f64],
                               acc_queue: &mut Vec<BinaryHeap<Reverse<(u64, usize)>>>| {
                ready_time[dep_t] = ready_time[dep_t].max(end + extra_comm);
                pending[dep_t] -= 1;
                if pending[dep_t] == 0 {
                    let a = self.node_costs[dep_t % n].acc;
                    acc_queue[a].push(Reverse((key(ready_time[dep_t]), dep_t)));
                }
            };
            // same-image graph successors
            for (j, node) in graph.nodes.iter().enumerate() {
                if node.deps.contains(&i) {
                    let comm = self.node_costs[j].comm_in_s;
                    release(b * n + j, comm, &mut pending, &mut ready_time, &mut acc_queue);
                }
            }
            // next batch, same node
            if b + 1 < batch {
                release((b + 1) * n + i, 0.0, &mut pending, &mut ready_time, &mut acc_queue);
            }
        }

        // DDR serialization bound (forwarding off): the shared link caps
        // the issue rate regardless of acc overlap.
        let ddr_floor = batch as f64 * self.ddr_per_image_s(platform);
        let latency = makespan.max(ddr_floor);
        let ops = (batch as u64 * graph.ops_per_image()) as f64;
        let tops = ops / latency / 1e12;
        Eval {
            batch,
            latency_s: latency,
            tops,
            gops_per_w: energy::gops_per_w(platform, tops),
        }
    }

    /// The coarse closed-form estimate (chain + (B-1) x bottleneck), kept
    /// for the latency-throughput intuition in docs; [`Self::evaluate`]
    /// supersedes it for all reported numbers.
    pub fn closed_form(&self, platform: &Platform, batch: usize) -> f64 {
        let chain = self.chain_s();
        let bottleneck = self
            .acc_busy_per_image()
            .into_iter()
            .fold(0.0f64, f64::max)
            .max(self.ddr_per_image_s(platform));
        chain + (batch.saturating_sub(1)) as f64 * bottleneck
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck190;
    use crate::graph::{vit_graph, DEIT_T};

    fn eval_of(assignment: Assignment, batch: usize) -> Eval {
        let p = vck190();
        let cal = Calib::default();
        let g = vit_graph(&DEIT_T);
        let ev = build_design(&p, &cal, &g, &assignment, Features::all(), true).unwrap();
        ev.evaluate(&p, &g, batch)
    }

    #[test]
    fn sequential_latency_scales_linearly() {
        let b1 = eval_of(Assignment::sequential(), 1);
        let b6 = eval_of(Assignment::sequential(), 6);
        let ratio = b6.latency_s / b1.latency_s;
        assert!((5.0..7.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn spatial_throughput_grows_with_batch() {
        let b1 = eval_of(Assignment::spatial(), 1);
        let b6 = eval_of(Assignment::spatial(), 6);
        assert!(
            b6.tops > 2.0 * b1.tops,
            "spatial should pipeline: {} vs {}",
            b6.tops,
            b1.tops
        );
    }

    #[test]
    fn sequential_beats_spatial_at_batch1() {
        // Fig. 2: point A (seq, b1) has lower latency than point C (spatial, b1).
        let seq = eval_of(Assignment::sequential(), 1);
        let spa = eval_of(Assignment::spatial(), 1);
        assert!(seq.latency_s < spa.latency_s, "{} vs {}", seq.latency_s, spa.latency_s);
    }

    #[test]
    fn spatial_beats_sequential_at_batch6_throughput() {
        // Fig. 2: point D (spatial, b6) beats point B (seq, b6) on TOPS.
        let seq = eval_of(Assignment::sequential(), 6);
        let spa = eval_of(Assignment::spatial(), 6);
        assert!(spa.tops > seq.tops, "{} vs {}", spa.tops, seq.tops);
    }

    #[test]
    fn forwarding_off_much_slower() {
        // §5.2.6: the CHARM-like baseline (DDR round-trips) is several times
        // slower than with on-chip forwarding.
        let p = vck190();
        let cal = Calib::default();
        let g = vit_graph(&DEIT_T);
        let a = Assignment::sequential();
        let with = build_design(&p, &cal, &g, &a, Features::all(), true).unwrap();
        let without = build_design(
            &p,
            &cal,
            &g,
            &a,
            Features { on_chip_forwarding: false, ..Features::all() },
            true,
        )
        .unwrap();
        let lw = with.evaluate(&p, &g, 6).latency_s;
        let lo = without.evaluate(&p, &g, 6).latency_s;
        assert!(lo > 2.0 * lw, "forwarding gain too small: {lo} vs {lw}");
    }

    #[test]
    fn pipeline_flag_reduces_latency() {
        let p = vck190();
        let cal = Calib::default();
        let g = vit_graph(&DEIT_T);
        let a = Assignment::spatial();
        let with = build_design(&p, &cal, &g, &a, Features::all(), true).unwrap();
        let without = build_design(
            &p,
            &cal,
            &g,
            &a,
            Features { fine_grained_pipeline: false, ..Features::all() },
            true,
        )
        .unwrap();
        assert!(
            without.evaluate(&p, &g, 6).latency_s > with.evaluate(&p, &g, 6).latency_s
        );
    }

    #[test]
    fn busy_sums_match_chain_when_no_comm() {
        let p = vck190();
        let cal = Calib::default();
        let g = vit_graph(&DEIT_T);
        let ev =
            build_design(&p, &cal, &g, &Assignment::sequential(), Features::all(), true)
                .unwrap();
        let busy: f64 = ev.acc_busy_per_image().iter().sum();
        // single acc, all comm Local -> chain == busy
        assert!((ev.chain_s() - busy).abs() < 1e-12);
    }
}
