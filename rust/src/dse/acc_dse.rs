//! Acc-Customization DSE — paper Algorithm 2.
//!
//! For each accelerator (in Layer→Acc schedule order) exhaustively search
//! the `config_vector (h1,w1,w2,A,B,C,Part_*)` space subject to Eq. 1
//! resource constraints, minimizing the accelerator's total per-image MM
//! time for its assigned workload. With `inter_acc_aware` the search prunes
//! configurations whose array parallelism cannot be divisibility-aligned
//! with already-fixed communicating accelerators, then *force-partitions*
//! the RAM banks (Fig. 8) so forwarding is conflict-free; without it the
//! paper's baseline searches everything and post-pays the repack penalty.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::partition::AccBudget;
use super::Assignment;
use crate::analytical::hmm::{self, AccConfig};
use crate::analytical::Calib;
use crate::arch::Platform;
use crate::graph::Graph;

/// Candidate values: integer solutions on the axes the paper sweeps.
pub const H_VALS: [u64; 5] = [8, 16, 32, 64, 128];
pub const ARR_VALS: [u64; 8] = [1, 2, 3, 4, 6, 8, 12, 16];

/// Precomputed per-class MM times over the whole config space.
///
/// `mm_time` is a pure function of (platform, calib, config, class dims,
/// pinned); across an enumeration of thousands of assignments the same
/// few-hundred-thousand evaluations repeat, so they are tabulated once per
/// (platform, calib, graph) and shared globally. On the single-core target
/// this is the dominant DSE speedup (see EXPERIMENTS.md §Perf).
pub struct CostTable {
    /// All (a, b, c) array shapes.
    pub abc: Vec<(u64, u64, u64)>,
    /// Local-memory-feasible (h1, w1, w2) workload triples.
    pub h: Vec<(u64, u64, u64)>,
    /// Class workload: (dims, node count) per LayerClass index.
    classes: Vec<(crate::graph::MmDims, f64)>,
    /// secs[((abc_i * h.len() + h_i) * nclass + class) * 2 + pinned]
    secs: Vec<f64>,
}

impl CostTable {
    pub fn build(platform: &Platform, calib: &Calib, graph: &Graph) -> CostTable {
        let mut abc = Vec::new();
        for &a in &ARR_VALS {
            for &b in &ARR_VALS {
                for &c in &ARR_VALS {
                    abc.push((a, b, c));
                }
            }
        }
        let mut h = Vec::new();
        for &h1 in &H_VALS {
            for &w1 in &H_VALS {
                for &w2 in &H_VALS {
                    let probe = AccConfig { h1, w1, w2, a: 1, b: 1, c: 1, part: (1, 1, 1) };
                    if probe.fits_local_mem(platform) {
                        h.push((h1, w1, w2));
                    }
                }
            }
        }
        let classes: Vec<(crate::graph::MmDims, f64)> = crate::graph::ALL_CLASSES
            .iter()
            .map(|&cl| {
                let nodes: Vec<_> = graph.nodes_of(cl).collect();
                (nodes[0].dims, nodes.len() as f64)
            })
            .collect();
        let nclass = classes.len();
        let mut secs = vec![0.0f64; abc.len() * h.len() * nclass * 2];
        let mut idx = 0;
        for &(a, b, c) in &abc {
            for &(h1, w1, w2) in &h {
                let cfg = AccConfig { h1, w1, w2, a, b, c, part: (a, 1, c) };
                for (dims, count) in &classes {
                    for pinned in [false, true] {
                        secs[idx] =
                            hmm::mm_time(platform, calib, &cfg, dims, pinned).seconds * count;
                        idx += 1;
                    }
                }
            }
        }
        CostTable { abc, h, classes, secs }
    }

    #[inline]
    pub fn secs(&self, abc_i: usize, h_i: usize, class: usize, pinned: bool) -> f64 {
        let nclass = self.classes.len();
        self.secs[((abc_i * self.h.len() + h_i) * nclass + class) * 2 + pinned as usize]
    }

    /// Global cache: one table per (platform, calib, graph model).
    pub fn cached(platform: &Platform, calib: &Calib, graph: &Graph) -> Arc<CostTable> {
        static CACHE: OnceLock<Mutex<HashMap<String, Arc<CostTable>>>> = OnceLock::new();
        let key = format!(
            "{}:{}:{}:{:?}",
            platform.name, graph.model, graph.macs_per_image, calib
        );
        let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        if let Some(t) = cache.lock().unwrap().get(&key) {
            return Arc::clone(t);
        }
        let t = Arc::new(CostTable::build(platform, calib, graph));
        cache.lock().unwrap().insert(key, Arc::clone(&t));
        t
    }
}

/// Outcome of customizing one accelerator.
#[derive(Clone, Debug)]
pub struct AccChoice {
    pub config: AccConfig,
    /// Per-image MM seconds for each class assigned to this acc
    /// (class index aligned with `Assignment::classes_on` order).
    pub mm_seconds: Vec<f64>,
    /// Number of configurations evaluated (Fig. 10's search-cost metric).
    pub evaluated: usize,
    /// Number pruned by the inter-acc alignment check.
    pub pruned: usize,
}

/// Search one accelerator's configuration (Algorithm 2 inner loop).
///
/// `neighbors` are configs of already-customized accelerators this acc
/// exchanges data with (upstream or downstream in the layer graph).
pub fn customize_acc(
    platform: &Platform,
    calib: &Calib,
    graph: &Graph,
    assignment: &Assignment,
    acc: usize,
    budget: &AccBudget,
    neighbors: &[(AccConfig, bool)], // (config, neighbor_is_upstream)
    inter_acc_aware: bool,
) -> Option<AccChoice> {
    let classes = assignment.classes_on(acc);
    if classes.is_empty() {
        return None;
    }
    let table = CostTable::cached(platform, calib, graph);
    // Per-class pinning: a node is weight-pinned only if it has weights
    // AND no attention class shares this acc (paper Sec. 4.3 (1)).
    let has_attention = assignment.has_attention(acc);
    let class_idx: Vec<(usize, bool)> = classes
        .iter()
        .map(|&c| {
            let pinned = !c.is_attention()
                && !has_attention
                && graph.nodes_of(c).next().unwrap().weight_bytes > 0;
            (c.index(), pinned)
        })
        .collect();

    let mut best: Option<(f64, AccConfig, Vec<f64>)> = None;
    let mut evaluated = 0usize;
    let mut pruned = 0usize;

    // Hot loop: (A,B,C) outer (drives every Eq. 1 constraint and the
    // alignment/force-partition outcome), precomputed cost-table sums for
    // the local-memory-feasible (h1,w1,w2) triples inner.
    for (abc_i, &(a, b, c)) in table.abc.iter().enumerate() {
        let base = AccConfig { h1: 8, w1: 8, w2: 8, a, b, c, part: (a, 1, c) };
        if base.aie() > budget.aie || base.plio() > budget.plio {
            continue;
        }
        let mut part = (a, 1, c);
        if inter_acc_aware {
            // Alignment pruning (Fig. 8), direction-aware: an upstream
            // neighbor's output (A, C) parallelism must divide into OUR
            // input (A, B); for a downstream neighbor it is OUR (A, C)
            // into THEIR (A, B).
            let ok = neighbors.iter().all(|(n, upstream)| {
                if *upstream {
                    n.aligned_with(&base)
                } else {
                    base.aligned_with(n)
                }
            });
            if !ok {
                pruned += table.h.len();
                continue;
            }
            // Force-partition the banks to the finest communicating
            // parallelism (Fig. 8b).
            let pa = neighbors.iter().map(|(n, _)| n.a).chain([a]).max().unwrap();
            let pc = neighbors.iter().map(|(n, _)| n.b).chain([c]).max().unwrap();
            part = (pa, 1, pc);
        }
        for (h_i, &(h1, w1, w2)) in table.h.iter().enumerate() {
            let cfg = AccConfig { h1, w1, w2, a, b, c, part };
            // RAM bank feasibility (depends on the tile size).
            if cfg.ram_banks(calib) > budget.bram + budget.uram * 2 {
                continue;
            }
            evaluated += 1;
            let mut total = 0.0;
            for &(ci, pinned) in &class_idx {
                total += table.secs(abc_i, h_i, ci, pinned);
            }
            if best.as_ref().map(|(bt, _, _)| total < *bt).unwrap_or(true) {
                let per_class = class_idx
                    .iter()
                    .map(|&(ci, pinned)| table.secs(abc_i, h_i, ci, pinned))
                    .collect();
                best = Some((total, cfg, per_class));
            }
        }
    }

    best.map(|(_, config, mm_seconds)| AccChoice {
        config,
        mm_seconds,
        evaluated,
        pruned,
    })
}

/// Customize all accelerators in schedule order (Algorithm 2 outer loop:
/// `trace_assignment` — accs are searched in first-use order so downstream
/// accs see their upstream neighbors' fixed configs).
pub fn customize_all(
    platform: &Platform,
    calib: &Calib,
    graph: &Graph,
    assignment: &Assignment,
    budgets: &[AccBudget],
    inter_acc_aware: bool,
) -> Option<Vec<AccChoice>> {
    let nacc = assignment.nacc();
    // first-use order over the topological node order
    let mut order = Vec::new();
    for n in &graph.nodes {
        let a = assignment.acc_of(n.class);
        if !order.contains(&a) {
            order.push(a);
        }
    }
    debug_assert_eq!(order.len(), nacc);

    let mut choices: Vec<Option<AccChoice>> = vec![None; nacc];
    for &acc in &order {
        // Neighbors: accs already customized that exchange tensors with
        // acc, tagged with the edge direction (upstream = they produce
        // what we consume).
        let mut neighbors: Vec<(AccConfig, bool)> = Vec::new();
        for n in &graph.nodes {
            let na = assignment.acc_of(n.class);
            for &d in &n.deps {
                let da = assignment.acc_of(graph.nodes[d].class);
                let other = if na == acc && da != acc {
                    Some((da, true)) // da produces into us
                } else if da == acc && na != acc {
                    Some((na, false)) // we produce into na
                } else {
                    None
                };
                if let Some((o, upstream)) = other {
                    if let Some(ch) = &choices[o] {
                        if !neighbors.contains(&(ch.config, upstream)) {
                            neighbors.push((ch.config, upstream));
                        }
                    }
                }
            }
        }
        let choice = customize_acc(
            platform,
            calib,
            graph,
            assignment,
            acc,
            &budgets[acc],
            &neighbors,
            inter_acc_aware,
        )?;
        choices[acc] = Some(choice);
    }
    Some(choices.into_iter().map(|c| c.unwrap()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck190;
    use crate::dse::partition::hw_partition;
    use crate::graph::{vit_graph, DEIT_T};

    fn setup() -> (crate::arch::Platform, Calib, Graph) {
        (vck190(), Calib::default(), vit_graph(&DEIT_T))
    }

    #[test]
    fn sequential_acc_uses_most_aies() {
        let (p, cal, g) = setup();
        let a = Assignment::sequential();
        let budgets = hw_partition(&p, &cal, &g, &a);
        let choices = customize_all(&p, &cal, &g, &a, &budgets, true).unwrap();
        assert_eq!(choices.len(), 1);
        let cfg = choices[0].config;
        assert!(cfg.aie() >= 128, "monolithic acc too small: {}", cfg.aie());
        assert!(cfg.aie() <= budgets[0].aie);
        assert!(cfg.plio() <= budgets[0].plio);
    }

    #[test]
    fn spatial_accs_all_realizable() {
        let (p, cal, g) = setup();
        let a = Assignment::spatial();
        let budgets = hw_partition(&p, &cal, &g, &a);
        let choices = customize_all(&p, &cal, &g, &a, &budgets, true).unwrap();
        assert_eq!(choices.len(), 8);
        let total_aie: u64 = choices.iter().map(|c| c.config.aie()).sum();
        assert!(total_aie <= p.aie_total);
        let total_plio: u64 = choices.iter().map(|c| c.config.plio()).sum();
        assert!(total_plio <= p.plio_total, "plio {total_plio}");
    }

    #[test]
    fn inter_acc_aware_prunes() {
        let (p, cal, g) = setup();
        let a = Assignment::new(vec![0, 0, 1, 1, 0, 0, 0, 0]);
        let budgets = hw_partition(&p, &cal, &g, &a);
        let aware = customize_all(&p, &cal, &g, &a, &budgets, true).unwrap();
        let naive = customize_all(&p, &cal, &g, &a, &budgets, false).unwrap();
        let pruned: usize = aware.iter().map(|c| c.pruned).sum();
        assert!(pruned > 0, "expected alignment pruning to fire");
        let ev_aware: usize = aware.iter().map(|c| c.evaluated).sum();
        let ev_naive: usize = naive.iter().map(|c| c.evaluated).sum();
        assert!(ev_aware < ev_naive, "{ev_aware} vs {ev_naive}");
    }

    #[test]
    fn aware_configs_are_aligned() {
        let (p, cal, g) = setup();
        let a = Assignment::spatial();
        let budgets = hw_partition(&p, &cal, &g, &a);
        let choices = customize_all(&p, &cal, &g, &a, &budgets, true).unwrap();
        // every graph edge crossing accs must be divisibility-aligned
        for n in &g.nodes {
            for &d in &n.deps {
                let pa = a.acc_of(g.nodes[d].class);
                let ca = a.acc_of(n.class);
                if pa != ca {
                    assert!(
                        choices[pa].config.aligned_with(&choices[ca].config),
                        "{} -> {} misaligned",
                        g.nodes[d].name,
                        n.name
                    );
                }
            }
        }
    }

    #[test]
    fn local_mem_always_respected() {
        let (p, cal, g) = setup();
        for a in [Assignment::sequential(), Assignment::spatial()] {
            let budgets = hw_partition(&p, &cal, &g, &a);
            for ch in customize_all(&p, &cal, &g, &a, &budgets, true).unwrap() {
                assert!(ch.config.fits_local_mem(&p));
            }
        }
    }
}
