//! Exhaustive assignment enumeration (the Fig. 10 "exhaustive search"
//! baseline, and the Table 7 per-acc-count design sweep).
//!
//! Assignments are set partitions of the 8 layer classes; Bell(8) = 4140
//! total, S(8,k) per exact accelerator count — small enough to enumerate
//! outright, which is what makes the EA-vs-exhaustive comparison honest.

use super::Assignment;
use crate::graph::ALL_CLASSES;

/// All canonical assignments using exactly `k` accelerators.
pub fn with_exactly(k: usize) -> Vec<Assignment> {
    all_up_to(k).into_iter().filter(|a| a.nacc() == k).collect()
}

/// All canonical assignments with at most `max_acc` accelerators
/// (restricted-growth strings: the canonical set-partition encoding, which
/// matches `Assignment::normalize`'s first-appearance labeling).
pub fn all_up_to(max_acc: usize) -> Vec<Assignment> {
    let n = ALL_CLASSES.len();
    let mut out = Vec::new();
    let mut cur = vec![0usize; n];
    fn rec(cur: &mut Vec<usize>, i: usize, max_used: usize, max_acc: usize, out: &mut Vec<Assignment>) {
        let n = cur.len();
        if i == n {
            out.push(Assignment { acc_of: cur.clone() });
            return;
        }
        for v in 0..=(max_used + 1).min(max_acc - 1) {
            cur[i] = v;
            rec(cur, i + 1, max_used.max(v), max_acc, out);
        }
    }
    // first element is always acc 0 in canonical form
    rec(&mut cur, 1, 0, max_acc.max(1), &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bell_number_of_8() {
        assert_eq!(all_up_to(8).len(), 4140);
    }

    #[test]
    fn stirling_counts() {
        // S(8,k): 1, 127, 966, 1701, 1050, 266, 28, 1
        for (k, s) in [(1, 1), (2, 127), (3, 966), (4, 1701), (5, 1050), (6, 266), (7, 28), (8, 1)] {
            assert_eq!(with_exactly(k).len(), s, "S(8,{k})");
        }
    }

    #[test]
    fn all_canonical() {
        for a in all_up_to(3) {
            let mut b = a.clone();
            b.normalize();
            assert_eq!(a.acc_of, b.acc_of);
        }
    }

    #[test]
    fn max_acc_respected() {
        assert!(all_up_to(2).iter().all(|a| a.nacc() <= 2));
        assert_eq!(all_up_to(1).len(), 1);
    }
}
