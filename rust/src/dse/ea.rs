//! Layer→Acc evolutionary search — paper Algorithm 1.
//!
//! Population of assignment genomes; fitness = throughput at the target
//! batch subject to the latency constraint; selection + single-point
//! crossover + mutation ("randomly exchange two layer-acc assignments");
//! elitist population update. Evaluations are memoized (genomes are tiny
//! and collide often) and fanned out over a thread pool.

use std::collections::HashMap;
use std::sync::Mutex;

use super::eval::{build_design, Evaluated};
use super::{Assignment, Eval};
use crate::analytical::{Calib, Features};
use crate::arch::Platform;
use crate::graph::{Graph, ALL_CLASSES};
use crate::util::rng::Rng;
use crate::util::threadpool::scope_map;

/// EA hyperparameters (paper: nAcc, nBat, nPop, nChild, nIter).
#[derive(Clone, Copy, Debug)]
pub struct EaParams {
    /// Max accelerators a genome may use (None = up to #classes).
    pub max_acc: Option<usize>,
    /// Batch size the fitness evaluates at (nBat).
    pub batch: usize,
    pub n_pop: usize,
    pub n_child: usize,
    pub n_iter: usize,
    /// Latency constraint (seconds); designs above it are infeasible.
    pub lat_cons: f64,
    pub seed: u64,
    pub threads: usize,
}

impl Default for EaParams {
    fn default() -> Self {
        EaParams {
            max_acc: None,
            batch: 6,
            n_pop: 24,
            n_child: 24,
            n_iter: 12,
            lat_cons: f64::INFINITY,
            seed: 0xDEED,
            threads: crate::util::threadpool::default_threads(),
        }
    }
}

/// Best design found + search accounting.
pub struct EaResult {
    pub best: Option<(Evaluated, Eval)>,
    /// (generation, best-feasible-throughput-so-far) trace for Fig. 10-style
    /// search-quality curves.
    pub trace: Vec<(usize, f64)>,
    /// Non-dominated feasible designs encountered during the search, on the
    /// (latency, throughput) plane at the search batch — the raw material
    /// for `ssr dse --emit-front` (sorted by latency ascending).
    pub pareto_candidates: Vec<(Assignment, Eval)>,
    pub designs_evaluated: usize,
    pub configs_evaluated: usize,
}

/// Run Algorithm 1: optimize throughput under `lat_cons`.
pub fn run_ea(
    platform: &Platform,
    calib: &Calib,
    graph: &Graph,
    features: Features,
    inter_acc_aware: bool,
    params: &EaParams,
) -> EaResult {
    let mut rng = Rng::new(params.seed);
    let max_acc = params.max_acc.unwrap_or(ALL_CLASSES.len()).max(1);

    // Memoized fitness: genome -> (tops or NEG if infeasible, eval).
    type CacheVal = Option<(Evaluated, Eval)>;
    let cache: Mutex<HashMap<Vec<usize>, ()>> = Mutex::new(HashMap::new());
    let mut evaluated: HashMap<Vec<usize>, CacheVal> = HashMap::new();
    let mut designs_evaluated = 0usize;
    let mut configs_evaluated = 0usize;

    let mut population: Vec<Assignment> = Vec::new();
    // Seed with the two pure strategies plus random genomes (the paper
    // initializes randomly; seeding the corners speeds convergence and is
    // what `layer_acc_assign(nAcc)` effectively covers).
    population.push(Assignment::sequential());
    if max_acc >= ALL_CLASSES.len() {
        population.push(Assignment::spatial());
    }
    while population.len() < params.n_pop {
        population.push(random_assignment(&mut rng, max_acc));
    }

    let mut best: Option<(Evaluated, Eval)> = None;
    let mut trace = Vec::new();

    let eval_batch = |genomes: &[Assignment],
                          evaluated: &mut HashMap<Vec<usize>, CacheVal>,
                          designs_evaluated: &mut usize,
                          configs_evaluated: &mut usize|
     -> Vec<f64> {
        // Collect the genomes not yet memoized, evaluate in parallel.
        let todo: Vec<Assignment> = genomes
            .iter()
            .filter(|g| !evaluated.contains_key(&g.acc_of))
            .filter(|g| {
                cache
                    .lock()
                    .unwrap()
                    .insert(g.acc_of.clone(), ())
                    .is_none()
            })
            .cloned()
            .collect();
        let results = scope_map(&todo, params.threads, |g| {
            build_design(platform, calib, graph, g, features, inter_acc_aware).map(|ev| {
                let e = ev.evaluate(platform, graph, params.batch);
                (ev, e)
            })
        });
        for (g, r) in todo.into_iter().zip(results) {
            *designs_evaluated += 1;
            if let Some((ev, _)) = &r {
                *configs_evaluated += ev.stats.configs_evaluated;
            }
            evaluated.insert(g.acc_of, r);
        }
        genomes
            .iter()
            .map(|g| fitness(evaluated.get(&g.acc_of).unwrap(), params.lat_cons))
            .collect()
    };

    let mut fit = eval_batch(
        &population,
        &mut evaluated,
        &mut designs_evaluated,
        &mut configs_evaluated,
    );
    update_best(&population, &evaluated, params.lat_cons, &mut best);
    trace.push((0, best_tops(&best)));

    for gen in 1..=params.n_iter {
        // Selection + single-point crossover (Algorithm 1 lines 8-12).
        let mut children = Vec::with_capacity(params.n_child);
        for _ in 0..params.n_child / 2 {
            let p1 = tournament(&mut rng, &population, &fit);
            let p2 = tournament(&mut rng, &population, &fit);
            let (c1, c2) = sp_crossover(&mut rng, p1, p2);
            children.push(c1);
            children.push(c2);
        }
        // Mutation (lines 13-18): exchange two classes' accs or reassign one.
        for ch in children.iter_mut() {
            if rng.bool(0.6) {
                mutate(&mut rng, ch, max_acc);
            }
        }
        let child_fit = eval_batch(
            &children,
            &mut evaluated,
            &mut designs_evaluated,
            &mut configs_evaluated,
        );
        update_best(&children, &evaluated, params.lat_cons, &mut best);

        // Elitist population update (lines 19-24): keep top n_pop.
        let mut all: Vec<(Assignment, f64)> = population
            .drain(..)
            .zip(fit.drain(..))
            .chain(children.into_iter().zip(child_fit))
            .collect();
        all.sort_by(|a, b| b.1.total_cmp(&a.1));
        all.truncate(params.n_pop);
        for (g, f) in all {
            population.push(g);
            fit.push(f);
        }
        trace.push((gen, best_tops(&best)));
    }

    let pareto_candidates = pareto_of_evaluated(&evaluated, params.lat_cons);
    EaResult { best, trace, pareto_candidates, designs_evaluated, configs_evaluated }
}

/// Non-dominated feasible (assignment, eval) pairs from the memo table.
/// The HashMap iteration order is arbitrary, so candidates are sorted into
/// a canonical order before pruning to keep the result deterministic.
fn pareto_of_evaluated(
    evaluated: &HashMap<Vec<usize>, Option<(Evaluated, Eval)>>,
    lat_cons: f64,
) -> Vec<(Assignment, Eval)> {
    use crate::dse::pareto::{pareto_indices, Point};
    let mut feasible: Vec<(&Vec<usize>, Eval)> = evaluated
        .iter()
        .filter_map(|(g, r)| r.as_ref().map(|(_, e)| (g, *e)))
        .filter(|(_, e)| e.latency_s <= lat_cons)
        .collect();
    feasible.sort_by(|(ga, a), (gb, b)| {
        a.latency_s
            .total_cmp(&b.latency_s)
            .then(b.tops.total_cmp(&a.tops))
            .then(ga.cmp(gb))
    });
    let points: Vec<Point> = feasible
        .iter()
        .map(|(g, e)| Point {
            latency_ms: e.latency_s * 1e3,
            tops: e.tops,
            batch: e.batch,
            nacc: g.iter().copied().max().unwrap_or(0) + 1,
        })
        .collect();
    pareto_indices(&points)
        .into_iter()
        .map(|i| (Assignment::new(feasible[i].0.clone()), feasible[i].1))
        .collect()
}

fn best_tops(best: &Option<(Evaluated, Eval)>) -> f64 {
    best.as_ref().map(|(_, e)| e.tops).unwrap_or(0.0)
}

fn fitness(r: &Option<(Evaluated, Eval)>, lat_cons: f64) -> f64 {
    match r {
        None => f64::NEG_INFINITY,
        Some((_, e)) if e.latency_s <= lat_cons => e.tops,
        // Infeasible designs get a strongly penalized but still ordered
        // fitness so the EA can climb back into the feasible region.
        Some((_, e)) => -e.latency_s,
    }
}

fn update_best(
    genomes: &[Assignment],
    evaluated: &HashMap<Vec<usize>, Option<(Evaluated, Eval)>>,
    lat_cons: f64,
    best: &mut Option<(Evaluated, Eval)>,
) {
    for g in genomes {
        if let Some(Some((ev, e))) = evaluated.get(&g.acc_of) {
            if e.latency_s <= lat_cons
                && best.as_ref().map(|(_, be)| e.tops > be.tops).unwrap_or(true)
            {
                *best = Some((ev.clone(), *e));
            }
        }
    }
}

fn random_assignment(rng: &mut Rng, max_acc: usize) -> Assignment {
    let nacc = 1 + rng.usize_below(max_acc);
    Assignment::new(
        (0..ALL_CLASSES.len()).map(|_| rng.usize_below(nacc)).collect(),
    )
}

fn tournament<'a>(rng: &mut Rng, pop: &'a [Assignment], fit: &[f64]) -> &'a Assignment {
    let i = rng.usize_below(pop.len());
    let j = rng.usize_below(pop.len());
    if fit[i] >= fit[j] {
        &pop[i]
    } else {
        &pop[j]
    }
}

fn sp_crossover(rng: &mut Rng, p1: &Assignment, p2: &Assignment) -> (Assignment, Assignment) {
    let cut = 1 + rng.usize_below(ALL_CLASSES.len() - 1);
    let mut c1 = p1.acc_of.clone();
    let mut c2 = p2.acc_of.clone();
    for i in cut..ALL_CLASSES.len() {
        std::mem::swap(&mut c1[i], &mut c2[i]);
    }
    (Assignment::new(c1), Assignment::new(c2))
}

fn mutate(rng: &mut Rng, a: &mut Assignment, max_acc: usize) {
    if rng.bool(0.5) {
        // exchange two layer-acc assignments (the paper's mutation)
        let i = rng.usize_below(ALL_CLASSES.len());
        let j = rng.usize_below(ALL_CLASSES.len());
        a.acc_of.swap(i, j);
    } else {
        // reassign one class to a random acc (possibly opening a new one)
        let i = rng.usize_below(ALL_CLASSES.len());
        a.acc_of[i] = rng.usize_below(max_acc);
    }
    a.normalize();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck190;
    use crate::graph::{vit_graph, DEIT_T};

    fn quick_params() -> EaParams {
        EaParams { n_pop: 8, n_child: 8, n_iter: 4, seed: 7, ..Default::default() }
    }

    #[test]
    fn ea_finds_feasible_design() {
        let p = vck190();
        let g = vit_graph(&DEIT_T);
        let r = run_ea(&p, &Calib::default(), &g, Features::all(), true, &quick_params());
        let (_, e) = r.best.expect("EA should find something");
        assert!(e.tops > 1.0, "tops={}", e.tops);
        assert!(r.designs_evaluated > 8);
    }

    #[test]
    fn ea_beats_or_matches_pure_strategies() {
        let p = vck190();
        let cal = Calib::default();
        let g = vit_graph(&DEIT_T);
        let params = EaParams { n_pop: 12, n_child: 12, n_iter: 6, seed: 3, ..Default::default() };
        let hybrid = run_ea(&p, &cal, &g, Features::all(), true, &params);
        let ht = best_tops(&hybrid.best);
        for a in [Assignment::sequential(), Assignment::spatial()] {
            let ev = build_design(&p, &cal, &g, &a, Features::all(), true).unwrap();
            let e = ev.evaluate(&p, &g, params.batch);
            assert!(
                ht >= e.tops * 0.999,
                "hybrid {ht} worse than {:?} {}",
                a.acc_of,
                e.tops
            );
        }
    }

    #[test]
    fn latency_constraint_respected() {
        let p = vck190();
        let g = vit_graph(&DEIT_T);
        let params = EaParams { lat_cons: 0.5e-3, batch: 1, ..quick_params() };
        let r = run_ea(&p, &Calib::default(), &g, Features::all(), true, &params);
        if let Some((_, e)) = r.best {
            assert!(e.latency_s <= 0.5e-3);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let p = vck190();
        let g = vit_graph(&DEIT_T);
        let r1 = run_ea(&p, &Calib::default(), &g, Features::all(), true, &quick_params());
        let r2 = run_ea(&p, &Calib::default(), &g, Features::all(), true, &quick_params());
        assert_eq!(best_tops(&r1.best), best_tops(&r2.best));
        assert_eq!(r1.trace, r2.trace);
    }

    #[test]
    fn trace_monotone_nondecreasing() {
        let p = vck190();
        let g = vit_graph(&DEIT_T);
        let r = run_ea(&p, &Calib::default(), &g, Features::all(), true, &quick_params());
        for w in r.trace.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn pareto_candidates_feasible_sorted_and_contain_best() {
        let p = vck190();
        let g = vit_graph(&DEIT_T);
        let r = run_ea(&p, &Calib::default(), &g, Features::all(), true, &quick_params());
        let (_, best) = r.best.as_ref().unwrap();
        assert!(!r.pareto_candidates.is_empty());
        for w in r.pareto_candidates.windows(2) {
            assert!(w[0].1.latency_s <= w[1].1.latency_s);
            assert!(w[0].1.tops <= w[1].1.tops, "front must trade latency for tops");
        }
        let best_on_front = r
            .pareto_candidates
            .iter()
            .map(|(_, e)| e.tops)
            .fold(0.0f64, f64::max);
        assert!((best_on_front - best.tops).abs() < 1e-9);
    }

    #[test]
    fn max_acc_one_recovers_sequential() {
        let p = vck190();
        let g = vit_graph(&DEIT_T);
        let params = EaParams { max_acc: Some(1), ..quick_params() };
        let r = run_ea(&p, &Calib::default(), &g, Features::all(), true, &params);
        let (ev, _) = r.best.unwrap();
        assert_eq!(ev.design.assignment.nacc(), 1);
    }
}
