//! The one traffic API: every workload a consumer can offer, as data.
//!
//! All load in the repo — single-device ramps, multi-model cluster mixes,
//! diurnal/flash-crowd traces, heavy-tailed bursts — flows through this
//! module as a [`TraceSpec`] and streams into the event loop as an
//! [`ArrivalStream`]:
//!
//! ```text
//!   RampSpec ─┐
//!   TrafficMix ├─ Into<TraceSpec> ──► ArrivalStream::from_trace
//!   TraceSpec ─┘      (classes:        (k-way merge of lazy per-class
//!    {model,           model +          generators, O(classes) memory)
//!     RateCurve,       curve +              │
//!     ArrivalProcess}) process)             ▼
//!                                  sim::device::run_timeline*
//! ```
//!
//! Consumers (`sim::serving::serve_ramp`, `sim::sweep::run_sweep`,
//! `cluster::provision::provision`, `cluster::sim::simulate_fleet`,
//! `cluster::controller::simulate_autoscale`) all accept
//! `impl Into<TraceSpec>`; [`RampSpec`] and [`TrafficMix`] survive as
//! thin constructors for the piecewise-constant Poisson special cases,
//! and their embedded paths generate **bit-identical** arrivals to the
//! pre-trace stream (pinned by `rust/tests/traffic_trace.rs`).
//!
//! History: `RampSpec`/`ClassArrivals`/`TrafficClass`/`TrafficMix`/
//! `ArrivalStream` moved here verbatim from `coordinator::scheduler`,
//! which re-exports them so pre-move paths keep compiling.

pub mod mix;
pub mod stream;
pub mod trace;

pub use mix::{ClassArrivals, RampSpec, TrafficClass, TrafficMix};
pub use stream::ArrivalStream;
pub use trace::{ArrivalProcess, RateCurve, TraceClass, TraceSpec};
