//! Piecewise-constant Poisson load generation: [`RampSpec`] ramps,
//! lazy per-class [`ClassArrivals`] generators, and the multi-model
//! [`TrafficClass`]/[`TrafficMix`] grouping.
//!
//! Moved verbatim from `coordinator::scheduler` when the traffic API was
//! unified under [`crate::traffic`] (the scheduler re-exports these names,
//! so old paths keep compiling). [`RampSpec`] survives as the thin
//! constructor for the piecewise-constant special case of a
//! [`crate::traffic::RateCurve`]; everything downstream consumes the
//! general [`crate::traffic::TraceSpec`].

use crate::util::rng::Rng;

/// Piecewise-constant arrival-rate ramp (the `--ramp a:b:c` flag): phase
/// `i` offers `rates_rps[i]` requests/s for `phase_s` seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct RampSpec {
    pub rates_rps: Vec<f64>,
    pub phase_s: f64,
}

impl RampSpec {
    /// Parse `"a:b:c"` (also accepts commas) into a ramp.
    pub fn parse(spec: &str, phase_s: f64) -> Result<RampSpec, String> {
        let rates: Result<Vec<f64>, _> = spec
            .split(|c| c == ':' || c == ',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| s.trim().parse::<f64>())
            .collect();
        let rates = rates.map_err(|e| format!("bad ramp '{spec}': {e}"))?;
        if rates.is_empty() {
            return Err(format!("ramp '{spec}' has no phases"));
        }
        if rates.iter().any(|r| !r.is_finite() || *r < 0.0) {
            return Err(format!("ramp '{spec}' has a negative or non-finite rate"));
        }
        if !(phase_s > 0.0 && phase_s.is_finite()) {
            return Err(format!("phase duration {phase_s} must be positive"));
        }
        Ok(RampSpec { rates_rps: rates, phase_s })
    }

    pub fn duration_s(&self) -> f64 {
        self.rates_rps.len() as f64 * self.phase_s
    }

    /// Offered rate at time `t` (0 outside the ramp).
    pub fn rate_at(&self, t: f64) -> f64 {
        if t < 0.0 {
            return 0.0;
        }
        self.rates_rps.get((t / self.phase_s) as usize).copied().unwrap_or(0.0)
    }

    /// Deterministic Poisson arrival times over the ramp (sorted). Each
    /// phase draws exponential gaps at its own rate; restarting at phase
    /// boundaries is exact for a Poisson process (memorylessness).
    ///
    /// Materializes the [`ClassArrivals`] stream — sims should consume
    /// the stream itself (via [`crate::traffic::ArrivalStream`]) and never
    /// hold the full timeline; this remains for callers that genuinely
    /// want the Vec.
    pub fn arrivals(&self, seed: u64) -> Vec<f64> {
        let mut stream = ClassArrivals::new(self, Rng::new(seed));
        let mut out = Vec::new();
        while let Some(t) = stream.next_arrival() {
            out.push(t);
        }
        out
    }
}

/// Lazy per-class Poisson arrival generator: the streaming form of
/// [`RampSpec::arrivals`], drawing one exponential gap per `next_arrival`
/// call from the same RNG in the same order — the two produce bit-equal
/// times (pinned by `class_arrivals_match_the_materializing_generator`).
/// O(1) memory regardless of how many arrivals the ramp offers.
#[derive(Clone, Debug)]
pub struct ClassArrivals {
    rng: Rng,
    rates_rps: Vec<f64>,
    phase_s: f64,
    phase: usize,
    t: f64,
}

impl ClassArrivals {
    pub fn new(ramp: &RampSpec, rng: Rng) -> ClassArrivals {
        ClassArrivals {
            rng,
            rates_rps: ramp.rates_rps.clone(),
            phase_s: ramp.phase_s,
            phase: 0,
            t: 0.0,
        }
    }

    /// Next arrival time, `None` once the ramp is exhausted. Zero-rate
    /// phases draw nothing (exactly like the materializing loop's
    /// `continue`), and the draw that overshoots a phase boundary is
    /// consumed, not reused — both invariants are what keep the stream
    /// bit-identical to the pre-streaming generator.
    pub fn next_arrival(&mut self) -> Option<f64> {
        while self.phase < self.rates_rps.len() {
            let rate = self.rates_rps[self.phase];
            if rate <= 0.0 {
                self.enter_phase(self.phase + 1);
                continue;
            }
            // t0 + phase_s, NOT (phase+1)*phase_s: the materializing
            // generator computed the boundary this way and the two can
            // differ by an ulp — which would shift an arrival across it.
            let t1 = self.phase as f64 * self.phase_s + self.phase_s;
            self.t += -(1.0 - self.rng.f64()).ln() / rate;
            if self.t >= t1 {
                self.enter_phase(self.phase + 1);
                continue;
            }
            return Some(self.t);
        }
        None
    }

    fn enter_phase(&mut self, p: usize) {
        self.phase = p;
        self.t = p as f64 * self.phase_s; // each phase restarts at its t0
    }
}

/// One model's offered load.
#[derive(Clone, Debug)]
pub struct TrafficClass {
    pub model: String,
    pub ramp: RampSpec,
}

/// A multi-model traffic mix: each class generates Poisson arrivals from
/// its own ramp on an independent split RNG stream, so adding a class
/// never perturbs another class's arrival times. The single-device sim
/// serves a single-class mix; the cluster router dispatches the general
/// case — both replay the same merged timeline format.
///
/// This is the all-Poisson, all-piecewise special case of a
/// [`crate::traffic::TraceSpec`] (which adds rate-curve and burst-process
/// choices per class); `From<&TrafficMix> for TraceSpec` embeds it.
#[derive(Clone, Debug)]
pub struct TrafficMix {
    pub classes: Vec<TrafficClass>,
}

impl TrafficMix {
    pub fn single(model: &str, ramp: RampSpec) -> TrafficMix {
        TrafficMix { classes: vec![TrafficClass { model: model.to_string(), ramp }] }
    }

    pub fn duration_s(&self) -> f64 {
        self.classes.iter().map(|c| c.ramp.duration_s()).fold(0.0, f64::max)
    }

    /// Merged `(arrival time, class index)` timeline, sorted by time with
    /// ties broken by class order — fully deterministic per seed.
    ///
    /// Materializes [`crate::traffic::ArrivalStream`] — sims consume the
    /// stream directly and keep memory O(classes); this remains for
    /// callers (and the differential tests) that want the whole Vec.
    pub fn arrivals(&self, seed: u64) -> Vec<(f64, usize)> {
        let mut stream = crate::traffic::ArrivalStream::new(self, seed);
        let mut out = Vec::new();
        while let Some(a) = crate::sim::device::ArrivalSource::pop(&mut stream) {
            out.push(a);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ramp_parse_and_rate_lookup() {
        let r = RampSpec::parse("1000:4000:1000", 0.5).unwrap();
        assert_eq!(r.rates_rps, vec![1000.0, 4000.0, 1000.0]);
        assert!((r.duration_s() - 1.5).abs() < 1e-12);
        assert_eq!(r.rate_at(0.1), 1000.0);
        assert_eq!(r.rate_at(0.7), 4000.0);
        assert_eq!(r.rate_at(2.0), 0.0);
        assert!(RampSpec::parse("", 0.5).is_err());
        assert!(RampSpec::parse("1:x", 0.5).is_err());
        assert!(RampSpec::parse("1:-2", 0.5).is_err());
        assert!(RampSpec::parse("1:2", 0.0).is_err());
    }

    #[test]
    fn poisson_arrivals_deterministic_sorted_in_bounds() {
        let r = RampSpec::parse("2000:500", 0.5).unwrap();
        let a = r.arrivals(42);
        let b = r.arrivals(42);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        assert!(a.iter().all(|&t| (0.0..1.0).contains(&t)));
        // ~1250 expected; allow wide Poisson slack
        assert!((800..1700).contains(&a.len()), "{} arrivals", a.len());
        assert_ne!(a, r.arrivals(43));
    }

    #[test]
    fn class_arrivals_match_the_materializing_generator() {
        // The pre-streaming RampSpec::arrivals body, verbatim: one RNG
        // across phases, zero-rate phases skipped without a draw, each
        // phase restarting at t0, the boundary-overshooting draw consumed.
        fn reference(ramp: &RampSpec, seed: u64) -> Vec<f64> {
            let mut rng = Rng::new(seed);
            let mut out = Vec::new();
            for (i, &rate) in ramp.rates_rps.iter().enumerate() {
                if rate <= 0.0 {
                    continue;
                }
                let t0 = i as f64 * ramp.phase_s;
                let t1 = t0 + ramp.phase_s;
                let mut t = t0;
                loop {
                    t += -(1.0 - rng.f64()).ln() / rate;
                    if t >= t1 {
                        break;
                    }
                    out.push(t);
                }
            }
            out
        }
        for (spec, phase) in [("2000:500", 0.5), ("0:3000:0:800", 0.2), ("1000", 1.0)] {
            let r = RampSpec::parse(spec, phase).unwrap();
            for seed in [1u64, 42, 0xC0FFEE] {
                let want = reference(&r, seed);
                let got = r.arrivals(seed);
                assert_eq!(got.len(), want.len(), "{spec} seed {seed}: count");
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits(), "{spec} seed {seed}: time bits");
                }
            }
        }
    }
}
