//! [`TraceSpec`]: the one serializable workload-trace type every traffic
//! consumer speaks.
//!
//! A trace is a set of traffic classes, each `{model, rate curve, burst
//! process}`:
//!
//! * [`RateCurve`] — the open-loop offered-rate shape: constant,
//!   piecewise-constant ramp (the [`RampSpec`] special case), diurnal
//!   sinusoid, or flash-crowd spike.
//! * [`ArrivalProcess`] — how individual arrivals fill that shape:
//!   Poisson (exponential gaps, as all pre-trace load was), or
//!   heavy-tailed renewal gaps (lognormal / Pareto) that burst far
//!   harder at the same average rate.
//!
//! The spec is pure data: [`crate::traffic::ArrivalStream::from_trace`]
//! turns it into the lazy `(time, class)` event stream the one event loop
//! consumes, in O(classes) memory. `RampSpec`/`TrafficMix` embed losslessly
//! (`From` impls below); the embedded path generates **bit-identical**
//! arrivals to the pre-trace stream, pinned by
//! `rust/tests/traffic_trace.rs`.

use std::path::Path;

use crate::sim::service::ServiceModel;
use crate::traffic::mix::{RampSpec, TrafficMix};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Offered-rate shape of one traffic class (requests/s over time).
/// `rate_at` is 0 outside `[0, duration_s)` for every variant.
#[derive(Clone, Debug, PartialEq)]
pub enum RateCurve {
    /// Flat `rate_rps` for `duration_s` seconds.
    Constant { rate_rps: f64, duration_s: f64 },
    /// Piecewise-constant phases — exactly a [`RampSpec`].
    Piecewise { rates_rps: Vec<f64>, phase_s: f64 },
    /// Day/night sinusoid: `base + amplitude * sin(2πt / period)`,
    /// clamped at 0 (an amplitude above base models dead-of-night lulls).
    Diurnal { base_rps: f64, amplitude_rps: f64, period_s: f64, duration_s: f64 },
    /// Flash crowd: `base` until `at_s`, a linear climb to `peak` over
    /// `ramp_s` (the onset a forecaster can front-run), then exponential
    /// decay back toward `base` with time constant `decay_s`.
    Flash { base_rps: f64, peak_rps: f64, at_s: f64, ramp_s: f64, decay_s: f64, duration_s: f64 },
}

impl RateCurve {
    pub fn duration_s(&self) -> f64 {
        match self {
            RateCurve::Constant { duration_s, .. }
            | RateCurve::Diurnal { duration_s, .. }
            | RateCurve::Flash { duration_s, .. } => *duration_s,
            RateCurve::Piecewise { rates_rps, phase_s } => rates_rps.len() as f64 * *phase_s,
        }
    }

    /// Offered rate at time `t` (0 outside the curve's span).
    pub fn rate_at(&self, t: f64) -> f64 {
        if t < 0.0 || t >= self.duration_s() {
            return 0.0;
        }
        match self {
            RateCurve::Constant { rate_rps, .. } => *rate_rps,
            RateCurve::Piecewise { rates_rps, phase_s } => {
                rates_rps.get((t / phase_s) as usize).copied().unwrap_or(0.0)
            }
            RateCurve::Diurnal { base_rps, amplitude_rps, period_s, .. } => {
                (base_rps + amplitude_rps * (2.0 * std::f64::consts::PI * t / period_s).sin())
                    .max(0.0)
            }
            RateCurve::Flash { base_rps, peak_rps, at_s, ramp_s, decay_s, .. } => {
                if t < *at_s {
                    *base_rps
                } else if t < at_s + ramp_s {
                    base_rps + (peak_rps - base_rps) * (t - at_s) / ramp_s
                } else {
                    base_rps + (peak_rps - base_rps) * (-(t - at_s - ramp_s) / decay_s).exp()
                }
            }
        }
    }

    /// Tight upper bound on the offered rate — the provisioner's sizing
    /// input and the thinning majorant for non-homogeneous Poisson
    /// generation. Piecewise uses the exact max-fold the provisioner
    /// always used on ramps, so sizing a `RampSpec` forecast is unchanged
    /// to the bit.
    pub fn peak_rps(&self) -> f64 {
        match self {
            RateCurve::Constant { rate_rps, .. } => *rate_rps,
            RateCurve::Piecewise { rates_rps, .. } => {
                rates_rps.iter().copied().fold(0.0, f64::max)
            }
            RateCurve::Diurnal { base_rps, amplitude_rps, .. } => base_rps + amplitude_rps,
            RateCurve::Flash { base_rps, peak_rps, .. } => base_rps.max(*peak_rps),
        }
    }

    /// Rate divided by `n` shards (exact division per rate, matching the
    /// sweep's historical `r / shards` arithmetic bit for bit).
    pub fn shard(&self, n: usize) -> RateCurve {
        let d = n as f64;
        match self.clone() {
            RateCurve::Constant { rate_rps, duration_s } => {
                RateCurve::Constant { rate_rps: rate_rps / d, duration_s }
            }
            RateCurve::Piecewise { rates_rps, phase_s } => RateCurve::Piecewise {
                rates_rps: rates_rps.iter().map(|r| r / d).collect(),
                phase_s,
            },
            RateCurve::Diurnal { base_rps, amplitude_rps, period_s, duration_s } => {
                RateCurve::Diurnal {
                    base_rps: base_rps / d,
                    amplitude_rps: amplitude_rps / d,
                    period_s,
                    duration_s,
                }
            }
            RateCurve::Flash { base_rps, peak_rps, at_s, ramp_s, decay_s, duration_s } => {
                RateCurve::Flash {
                    base_rps: base_rps / d,
                    peak_rps: peak_rps / d,
                    at_s,
                    ramp_s,
                    decay_s,
                    duration_s,
                }
            }
        }
    }

    /// Rate multiplied by `f` (Zipf popularity weighting).
    pub fn scaled(&self, f: f64) -> RateCurve {
        match self.clone() {
            RateCurve::Constant { rate_rps, duration_s } => {
                RateCurve::Constant { rate_rps: rate_rps * f, duration_s }
            }
            RateCurve::Piecewise { rates_rps, phase_s } => RateCurve::Piecewise {
                rates_rps: rates_rps.iter().map(|r| r * f).collect(),
                phase_s,
            },
            RateCurve::Diurnal { base_rps, amplitude_rps, period_s, duration_s } => {
                RateCurve::Diurnal {
                    base_rps: base_rps * f,
                    amplitude_rps: amplitude_rps * f,
                    period_s,
                    duration_s,
                }
            }
            RateCurve::Flash { base_rps, peak_rps, at_s, ramp_s, decay_s, duration_s } => {
                RateCurve::Flash {
                    base_rps: base_rps * f,
                    peak_rps: peak_rps * f,
                    at_s,
                    ramp_s,
                    decay_s,
                    duration_s,
                }
            }
        }
    }

    /// The ramp this curve is, when it is one: `Piecewise` verbatim,
    /// `Constant` as a single phase. The Poisson generator takes this
    /// road so ramp-shaped traces replay on the exact pre-trace
    /// [`crate::traffic::ClassArrivals`] path.
    pub fn as_ramp(&self) -> Option<RampSpec> {
        match self {
            RateCurve::Piecewise { rates_rps, phase_s } => {
                Some(RampSpec { rates_rps: rates_rps.clone(), phase_s: *phase_s })
            }
            RateCurve::Constant { rate_rps, duration_s } => {
                Some(RampSpec { rates_rps: vec![*rate_rps], phase_s: *duration_s })
            }
            _ => None,
        }
    }

    fn validate(&self) -> Result<(), String> {
        let fin = |v: f64, what: &str| {
            if v.is_finite() && v >= 0.0 {
                Ok(())
            } else {
                Err(format!("curve {what} {v} must be finite and non-negative"))
            }
        };
        let pos = |v: f64, what: &str| {
            if v.is_finite() && v > 0.0 {
                Ok(())
            } else {
                Err(format!("curve {what} {v} must be positive"))
            }
        };
        match self {
            RateCurve::Constant { rate_rps, duration_s } => {
                fin(*rate_rps, "rate_rps")?;
                pos(*duration_s, "duration_s")
            }
            RateCurve::Piecewise { rates_rps, phase_s } => {
                if rates_rps.is_empty() {
                    return Err("piecewise curve has no phases".into());
                }
                for &r in rates_rps {
                    fin(r, "phase rate")?;
                }
                pos(*phase_s, "phase_s")
            }
            RateCurve::Diurnal { base_rps, amplitude_rps, period_s, duration_s } => {
                fin(*base_rps, "base_rps")?;
                fin(*amplitude_rps, "amplitude_rps")?;
                pos(*period_s, "period_s")?;
                pos(*duration_s, "duration_s")
            }
            RateCurve::Flash { base_rps, peak_rps, at_s, ramp_s, decay_s, duration_s } => {
                fin(*base_rps, "base_rps")?;
                fin(*peak_rps, "peak_rps")?;
                fin(*at_s, "at_s")?;
                fin(*ramp_s, "ramp_s")?;
                pos(*decay_s, "decay_s")?;
                pos(*duration_s, "duration_s")
            }
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            RateCurve::Constant { .. } => "constant",
            RateCurve::Piecewise { .. } => "piecewise",
            RateCurve::Diurnal { .. } => "diurnal",
            RateCurve::Flash { .. } => "flash",
        }
    }

    fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("kind".to_string(), Json::Str(self.kind().to_string()));
        match self {
            RateCurve::Constant { rate_rps, duration_s } => {
                m.insert("rate_rps".to_string(), Json::Num(*rate_rps));
                m.insert("duration_s".to_string(), Json::Num(*duration_s));
            }
            RateCurve::Piecewise { rates_rps, phase_s } => {
                m.insert(
                    "rates_rps".to_string(),
                    Json::Arr(rates_rps.iter().map(|&r| Json::Num(r)).collect()),
                );
                m.insert("phase_s".to_string(), Json::Num(*phase_s));
            }
            RateCurve::Diurnal { base_rps, amplitude_rps, period_s, duration_s } => {
                m.insert("base_rps".to_string(), Json::Num(*base_rps));
                m.insert("amplitude_rps".to_string(), Json::Num(*amplitude_rps));
                m.insert("period_s".to_string(), Json::Num(*period_s));
                m.insert("duration_s".to_string(), Json::Num(*duration_s));
            }
            RateCurve::Flash { base_rps, peak_rps, at_s, ramp_s, decay_s, duration_s } => {
                m.insert("base_rps".to_string(), Json::Num(*base_rps));
                m.insert("peak_rps".to_string(), Json::Num(*peak_rps));
                m.insert("at_s".to_string(), Json::Num(*at_s));
                m.insert("ramp_s".to_string(), Json::Num(*ramp_s));
                m.insert("decay_s".to_string(), Json::Num(*decay_s));
                m.insert("duration_s".to_string(), Json::Num(*duration_s));
            }
        }
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<RateCurve, String> {
        let num = |k: &str| {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("curve missing '{k}'"))
        };
        match j.get("kind").and_then(Json::as_str) {
            Some("constant") => Ok(RateCurve::Constant {
                rate_rps: num("rate_rps")?,
                duration_s: num("duration_s")?,
            }),
            Some("piecewise") => {
                let rates: Vec<f64> = j
                    .get("rates_rps")
                    .and_then(Json::as_arr)
                    .ok_or("curve missing 'rates_rps'")?
                    .iter()
                    .map(|x| x.as_f64().ok_or("bad phase rate"))
                    .collect::<Result<_, _>>()?;
                Ok(RateCurve::Piecewise { rates_rps: rates, phase_s: num("phase_s")? })
            }
            Some("diurnal") => Ok(RateCurve::Diurnal {
                base_rps: num("base_rps")?,
                amplitude_rps: num("amplitude_rps")?,
                period_s: num("period_s")?,
                duration_s: num("duration_s")?,
            }),
            Some("flash") => Ok(RateCurve::Flash {
                base_rps: num("base_rps")?,
                peak_rps: num("peak_rps")?,
                at_s: num("at_s")?,
                ramp_s: num("ramp_s")?,
                decay_s: num("decay_s")?,
                duration_s: num("duration_s")?,
            }),
            Some(k) => Err(format!("unknown curve kind '{k}'")),
            None => Err("curve missing 'kind'".into()),
        }
    }

    fn describe(&self) -> String {
        match self {
            RateCurve::Constant { rate_rps, duration_s } => {
                format!("constant {rate_rps:.0} rps for {duration_s}s")
            }
            RateCurve::Piecewise { rates_rps, phase_s } => {
                let phases: Vec<String> = rates_rps.iter().map(|r| format!("{r:.0}")).collect();
                format!("ramp {} @ {phase_s}s/phase", phases.join(":"))
            }
            RateCurve::Diurnal { base_rps, amplitude_rps, period_s, duration_s } => format!(
                "diurnal {base_rps:.0}±{amplitude_rps:.0} rps, period {period_s}s, for {duration_s}s"
            ),
            RateCurve::Flash { base_rps, peak_rps, at_s, ramp_s, decay_s, duration_s } => format!(
                "flash {base_rps:.0}→{peak_rps:.0} rps at {at_s}s (ramp {ramp_s}s, decay {decay_s}s), for {duration_s}s"
            ),
        }
    }
}

impl From<&RampSpec> for RateCurve {
    fn from(r: &RampSpec) -> RateCurve {
        RateCurve::Piecewise { rates_rps: r.rates_rps.clone(), phase_s: r.phase_s }
    }
}

/// How individual arrivals fill a [`RateCurve`]. All variants hit the
/// curve's average rate; they differ in gap dispersion — heavy tails
/// cluster arrivals into bursts the mean-rate view never shows.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Exponential gaps — every pre-trace workload.
    Poisson,
    /// Renewal gaps `exp(σZ − σ²/2) / rate` (mean 1/rate): moderate
    /// bursts, heavier with `sigma`.
    LognormalGaps { sigma: f64 },
    /// Renewal gaps from a Pareto with shape `alpha` (> 1) scaled to mean
    /// 1/rate: rare huge gaps balanced by dense bursts.
    ParetoGaps { alpha: f64 },
}

impl ArrivalProcess {
    /// One mean-1 inter-arrival draw (divide by the local rate to place
    /// the next arrival). Poisson draws `-ln(1-u)` — one uniform; the
    /// lognormal draws two (Box–Muller); Pareto draws one.
    pub fn mean1_gap(&self, rng: &mut Rng) -> f64 {
        match self {
            ArrivalProcess::Poisson => -(1.0 - rng.f64()).ln(),
            ArrivalProcess::LognormalGaps { sigma } => {
                let u1 = rng.f64();
                let u2 = rng.f64();
                let z = (-2.0 * (1.0 - u1).ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
                (sigma * z - sigma * sigma / 2.0).exp()
            }
            ArrivalProcess::ParetoGaps { alpha } => {
                let xm = (alpha - 1.0) / alpha; // scale for mean 1
                xm / (1.0 - rng.f64()).powf(1.0 / alpha)
            }
        }
    }

    fn validate(&self) -> Result<(), String> {
        match self {
            ArrivalProcess::Poisson => Ok(()),
            ArrivalProcess::LognormalGaps { sigma } => {
                if sigma.is_finite() && *sigma > 0.0 {
                    Ok(())
                } else {
                    Err(format!("lognormal sigma {sigma} must be positive"))
                }
            }
            ArrivalProcess::ParetoGaps { alpha } => {
                if alpha.is_finite() && *alpha > 1.0 {
                    Ok(())
                } else {
                    Err(format!("pareto alpha {alpha} must exceed 1 (finite mean)"))
                }
            }
        }
    }

    fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        match self {
            ArrivalProcess::Poisson => {
                m.insert("kind".to_string(), Json::Str("poisson".to_string()));
            }
            ArrivalProcess::LognormalGaps { sigma } => {
                m.insert("kind".to_string(), Json::Str("lognormal".to_string()));
                m.insert("sigma".to_string(), Json::Num(*sigma));
            }
            ArrivalProcess::ParetoGaps { alpha } => {
                m.insert("kind".to_string(), Json::Str("pareto".to_string()));
                m.insert("alpha".to_string(), Json::Num(*alpha));
            }
        }
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<ArrivalProcess, String> {
        match j.get("kind").and_then(Json::as_str) {
            Some("poisson") => Ok(ArrivalProcess::Poisson),
            Some("lognormal") => Ok(ArrivalProcess::LognormalGaps {
                sigma: j
                    .get("sigma")
                    .and_then(Json::as_f64)
                    .ok_or("lognormal process missing 'sigma'")?,
            }),
            Some("pareto") => Ok(ArrivalProcess::ParetoGaps {
                alpha: j
                    .get("alpha")
                    .and_then(Json::as_f64)
                    .ok_or("pareto process missing 'alpha'")?,
            }),
            Some(k) => Err(format!("unknown process kind '{k}'")),
            None => Err("process missing 'kind'".into()),
        }
    }

    fn describe(&self) -> String {
        match self {
            ArrivalProcess::Poisson => "poisson".to_string(),
            ArrivalProcess::LognormalGaps { sigma } => format!("lognormal(σ={sigma})"),
            ArrivalProcess::ParetoGaps { alpha } => format!("pareto(α={alpha})"),
        }
    }
}

/// One traffic class of a [`TraceSpec`]: which model, what rate shape,
/// what burst process, and what per-launch service-time distribution
/// ([`ServiceModel::Deterministic`] reproduces the pre-noise sims bit
/// for bit and serializes to nothing — old artifacts load unchanged).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceClass {
    pub model: String,
    pub curve: RateCurve,
    pub process: ArrivalProcess,
    pub service: ServiceModel,
}

/// The one workload-trace type every traffic consumer accepts
/// (`serve_ramp`, `run_sweep`, `provision`, `simulate_fleet`,
/// `simulate_autoscale` all take `impl Into<TraceSpec>`). Pure data,
/// serializable (`ssr trace synth|show`, `--trace trace.json`);
/// [`crate::traffic::ArrivalStream::from_trace`] streams it lazily.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSpec {
    pub classes: Vec<TraceClass>,
}

impl TraceSpec {
    /// Build and validate a trace.
    pub fn new(classes: Vec<TraceClass>) -> Result<TraceSpec, String> {
        let t = TraceSpec { classes };
        t.validate()?;
        Ok(t)
    }

    /// One-class trace.
    pub fn single(model: &str, curve: RateCurve, process: ArrivalProcess) -> TraceSpec {
        TraceSpec {
            classes: vec![TraceClass {
                model: model.to_string(),
                curve,
                process,
                service: ServiceModel::Deterministic,
            }],
        }
    }

    /// The same trace with every class's service model replaced (the CLI
    /// `--service` override).
    pub fn with_service(mut self, service: &ServiceModel) -> TraceSpec {
        for c in &mut self.classes {
            c.service = service.clone();
        }
        self
    }

    /// Service model for `model`'s traffic: the first class serving that
    /// model wins (same first-occurrence rule as [`TraceSpec::models`]);
    /// unknown models fall back to `Deterministic`.
    pub fn service_for(&self, model: &str) -> ServiceModel {
        self.classes
            .iter()
            .find(|c| c.model == model)
            .map(|c| c.service.clone())
            .unwrap_or(ServiceModel::Deterministic)
    }

    /// Zipf model-popularity synthesis: class `k` (1-based rank) gets the
    /// shared `curve` scaled by `k^-exponent`, normalized so the classes
    /// sum to the curve's offered rate. Exponent 0 is a uniform split.
    pub fn zipf_mix(
        models: &[&str],
        curve: &RateCurve,
        process: ArrivalProcess,
        exponent: f64,
    ) -> Result<TraceSpec, String> {
        if models.is_empty() {
            return Err("zipf mix needs at least one model".into());
        }
        if !(exponent.is_finite() && exponent >= 0.0) {
            return Err(format!("zipf exponent {exponent} must be finite and non-negative"));
        }
        let weights: Vec<f64> =
            (1..=models.len()).map(|k| (k as f64).powf(-exponent)).collect();
        let total: f64 = weights.iter().sum();
        let classes = models
            .iter()
            .zip(&weights)
            .map(|(m, w)| TraceClass {
                model: m.to_string(),
                curve: curve.scaled(w / total),
                process,
                service: ServiceModel::Deterministic,
            })
            .collect();
        TraceSpec::new(classes)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.classes.is_empty() {
            return Err("trace has no classes".into());
        }
        for (i, c) in self.classes.iter().enumerate() {
            if c.model.is_empty() {
                return Err(format!("trace class {i} has an empty model"));
            }
            c.curve.validate().map_err(|e| format!("trace class {i}: {e}"))?;
            c.process.validate().map_err(|e| format!("trace class {i}: {e}"))?;
            c.service.validate().map_err(|e| format!("trace class {i}: {e}"))?;
        }
        Ok(())
    }

    /// Run length: the longest class span.
    pub fn duration_s(&self) -> f64 {
        self.classes.iter().map(|c| c.curve.duration_s()).fold(0.0, f64::max)
    }

    /// Sizing peak: the sum of per-class peaks — exact for one class,
    /// conservative for many (classes may peak at different times).
    pub fn peak_rps(&self) -> f64 {
        self.classes.iter().map(|c| c.curve.peak_rps()).sum()
    }

    /// Every class's rate divided by `n` (the sweep's traffic shards).
    pub fn shard(&self, n: usize) -> TraceSpec {
        TraceSpec {
            classes: self
                .classes
                .iter()
                .map(|c| TraceClass {
                    model: c.model.clone(),
                    curve: c.curve.shard(n),
                    process: c.process,
                    service: c.service.clone(),
                })
                .collect(),
        }
    }

    /// Distinct models in class order (first occurrence wins).
    pub fn models(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for c in &self.classes {
            if !out.iter().any(|m| m == &c.model) {
                out.push(c.model.clone());
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let classes: Vec<Json> = self
            .classes
            .iter()
            .map(|c| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("model".to_string(), Json::Str(c.model.clone()));
                m.insert("curve".to_string(), c.curve.to_json());
                m.insert("process".to_string(), c.process.to_json());
                // Deterministic is the implicit default: omitting it keeps
                // pre-noise trace artifacts byte-identical.
                if !c.service.is_deterministic() {
                    m.insert("service".to_string(), c.service.to_json());
                }
                Json::Obj(m)
            })
            .collect();
        let mut m = std::collections::BTreeMap::new();
        m.insert("classes".to_string(), Json::Arr(classes));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<TraceSpec, String> {
        let mut classes = Vec::new();
        for (i, c) in j
            .get("classes")
            .and_then(Json::as_arr)
            .ok_or("trace missing 'classes'")?
            .iter()
            .enumerate()
        {
            classes.push(TraceClass {
                model: c
                    .get("model")
                    .and_then(Json::as_str)
                    .ok_or_else(|| format!("trace class {i} missing 'model'"))?
                    .to_string(),
                curve: RateCurve::from_json(
                    c.get("curve").ok_or_else(|| format!("trace class {i} missing 'curve'"))?,
                )?,
                process: ArrivalProcess::from_json(
                    c.get("process")
                        .ok_or_else(|| format!("trace class {i} missing 'process'"))?,
                )?,
                service: match c.get("service") {
                    Some(s) => ServiceModel::from_json(s)
                        .map_err(|e| format!("trace class {i}: {e}"))?,
                    None => ServiceModel::Deterministic,
                },
            });
        }
        TraceSpec::new(classes)
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")
    }

    pub fn load(path: &Path) -> Result<TraceSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        TraceSpec::from_json(&Json::parse(&text)?)
    }

    /// One line per class, for CLI output.
    pub fn describe(&self) -> String {
        let mut out = format!(
            "trace: {} class(es), {:.2} s, peak {:.0} rps\n",
            self.classes.len(),
            self.duration_s(),
            self.peak_rps()
        );
        for (i, c) in self.classes.iter().enumerate() {
            let svc = if c.service.is_deterministic() {
                String::new()
            } else {
                format!("  svc {}", c.service.label())
            };
            out.push_str(&format!(
                "  [{i}] {:<12} {:<16} {}{svc}\n",
                c.model,
                c.process.describe(),
                c.curve.describe()
            ));
        }
        out
    }
}

/// A bare ramp is a one-class Poisson trace. The class keeps the
/// placeholder model name `"trace"`: every consumer that accepts a bare
/// `&RampSpec` (single-device `serve_ramp`/`run_sweep`, `provision`)
/// routes by device index or peak rate, never by model name.
impl From<&RampSpec> for TraceSpec {
    fn from(r: &RampSpec) -> TraceSpec {
        TraceSpec::single("trace", RateCurve::from(r), ArrivalProcess::Poisson)
    }
}

impl From<RampSpec> for TraceSpec {
    fn from(r: RampSpec) -> TraceSpec {
        TraceSpec::from(&r)
    }
}

impl From<&TrafficMix> for TraceSpec {
    fn from(mix: &TrafficMix) -> TraceSpec {
        TraceSpec {
            classes: mix
                .classes
                .iter()
                .map(|c| TraceClass {
                    model: c.model.clone(),
                    curve: RateCurve::from(&c.ramp),
                    process: ArrivalProcess::Poisson,
                    service: ServiceModel::Deterministic,
                })
                .collect(),
        }
    }
}

impl From<TrafficMix> for TraceSpec {
    fn from(mix: TrafficMix) -> TraceSpec {
        TraceSpec::from(&mix)
    }
}

impl From<&TraceSpec> for TraceSpec {
    fn from(t: &TraceSpec) -> TraceSpec {
        t.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_rate_at_and_peak_per_variant() {
        let c = RateCurve::Constant { rate_rps: 500.0, duration_s: 2.0 };
        assert_eq!(c.rate_at(1.0), 500.0);
        assert_eq!(c.rate_at(2.0), 0.0);
        assert_eq!(c.rate_at(-0.1), 0.0);
        assert_eq!(c.peak_rps(), 500.0);

        let p = RateCurve::Piecewise { rates_rps: vec![100.0, 400.0], phase_s: 0.5 };
        assert_eq!(p.rate_at(0.6), 400.0);
        assert_eq!(p.peak_rps(), 400.0);
        assert!((p.duration_s() - 1.0).abs() < 1e-12);

        let d = RateCurve::Diurnal {
            base_rps: 1000.0,
            amplitude_rps: 600.0,
            period_s: 4.0,
            duration_s: 8.0,
        };
        assert!((d.rate_at(1.0) - 1600.0).abs() < 1e-9); // sin peak at T/4
        assert!((d.rate_at(3.0) - 400.0).abs() < 1e-9); // trough at 3T/4
        assert_eq!(d.peak_rps(), 1600.0);
        // amplitude above base clamps at zero instead of going negative
        let lull = RateCurve::Diurnal {
            base_rps: 100.0,
            amplitude_rps: 300.0,
            period_s: 4.0,
            duration_s: 8.0,
        };
        assert_eq!(lull.rate_at(3.0), 0.0);

        let f = RateCurve::Flash {
            base_rps: 1000.0,
            peak_rps: 5000.0,
            at_s: 1.0,
            ramp_s: 0.5,
            decay_s: 0.25,
            duration_s: 3.0,
        };
        assert_eq!(f.rate_at(0.5), 1000.0);
        assert!((f.rate_at(1.25) - 3000.0).abs() < 1e-9); // halfway up the ramp
        assert!((f.rate_at(1.5) - 5000.0).abs() < 1e-9); // spike top
        let decayed = f.rate_at(1.75); // one time constant into the decay
        assert!((decayed - (1000.0 + 4000.0 * (-1.0f64).exp())).abs() < 1e-9);
        assert_eq!(f.peak_rps(), 5000.0);
    }

    #[test]
    fn piecewise_peak_and_shard_match_ramp_arithmetic() {
        // The provisioner folded max over ramp rates and the sweep divided
        // each rate by the shard count; the curve must reproduce both to
        // the bit so ramp-driven sizing and sweeps are unchanged.
        let rates = [3000.0, 9000.0, 3000.0, 0.1 + 0.2];
        let curve = RateCurve::Piecewise { rates_rps: rates.to_vec(), phase_s: 0.25 };
        let fold = rates.iter().copied().fold(0.0, f64::max);
        assert_eq!(curve.peak_rps().to_bits(), fold.to_bits());
        let sharded = curve.shard(7);
        let RateCurve::Piecewise { rates_rps, .. } = &sharded else { panic!() };
        for (s, r) in rates_rps.iter().zip(&rates) {
            assert_eq!(s.to_bits(), (r / 7.0f64).to_bits());
        }
    }

    #[test]
    fn mean1_gaps_have_unit_mean() {
        let mut rng = Rng::new(0x7AFF1C);
        for p in [
            ArrivalProcess::Poisson,
            ArrivalProcess::LognormalGaps { sigma: 1.0 },
            ArrivalProcess::ParetoGaps { alpha: 2.5 },
        ] {
            let n = 200_000;
            let mean: f64 = (0..n).map(|_| p.mean1_gap(&mut rng)).sum::<f64>() / n as f64;
            assert!(
                (mean - 1.0).abs() < 0.05,
                "{p:?}: empirical mean {mean} should be ~1"
            );
        }
    }

    #[test]
    fn zipf_mix_weights_and_validation() {
        let curve = RateCurve::Constant { rate_rps: 1000.0, duration_s: 1.0 };
        let t =
            TraceSpec::zipf_mix(&["a", "b", "c"], &curve, ArrivalProcess::Poisson, 1.0).unwrap();
        assert_eq!(t.classes.len(), 3);
        // weights 1, 1/2, 1/3 normalized: class rates sum to the base rate
        let total: f64 = t.classes.iter().map(|c| c.curve.peak_rps()).sum();
        assert!((total - 1000.0).abs() < 1e-9);
        let r0 = t.classes[0].curve.peak_rps();
        let r1 = t.classes[1].curve.peak_rps();
        assert!((r0 / r1 - 2.0).abs() < 1e-9, "rank 1 is twice rank 2");
        // exponent 0 splits uniformly
        let u = TraceSpec::zipf_mix(&["a", "b"], &curve, ArrivalProcess::Poisson, 0.0).unwrap();
        assert!((u.classes[0].curve.peak_rps() - 500.0).abs() < 1e-9);
        assert!(TraceSpec::zipf_mix(&[], &curve, ArrivalProcess::Poisson, 1.0).is_err());
        assert!(TraceSpec::zipf_mix(&["a"], &curve, ArrivalProcess::Poisson, -1.0).is_err());
    }

    #[test]
    fn validation_rejects_malformed_specs() {
        assert!(TraceSpec::new(vec![]).is_err());
        let bad_curve = RateCurve::Constant { rate_rps: -1.0, duration_s: 1.0 };
        assert!(TraceSpec::new(vec![TraceClass {
            model: "m".into(),
            curve: bad_curve,
            process: ArrivalProcess::Poisson,
            service: ServiceModel::Deterministic,
        }])
        .is_err());
        assert!(TraceSpec::single(
            "m",
            RateCurve::Constant { rate_rps: 1.0, duration_s: 1.0 },
            ArrivalProcess::Poisson
        )
        .with_service(&ServiceModel::LognormalFactor { sigma: -1.0 })
        .validate()
        .is_err());
        assert!(RateCurve::Piecewise { rates_rps: vec![], phase_s: 0.5 }.validate().is_err());
        assert!(RateCurve::Diurnal {
            base_rps: 1.0,
            amplitude_rps: 1.0,
            period_s: 0.0,
            duration_s: 1.0
        }
        .validate()
        .is_err());
        assert!(RateCurve::Flash {
            base_rps: 1.0,
            peak_rps: 2.0,
            at_s: 0.5,
            ramp_s: 0.1,
            decay_s: 0.0,
            duration_s: 1.0
        }
        .validate()
        .is_err());
        assert!(ArrivalProcess::LognormalGaps { sigma: 0.0 }.validate().is_err());
        assert!(ArrivalProcess::ParetoGaps { alpha: 1.0 }.validate().is_err());
        assert!(ArrivalProcess::ParetoGaps { alpha: 1.5 }.validate().is_ok());
        let empty_model = TraceSpec::single("", RateCurve::Constant { rate_rps: 1.0, duration_s: 1.0 }, ArrivalProcess::Poisson);
        assert!(empty_model.validate().is_err());
    }

    #[test]
    fn service_models_ride_through_json_and_default_to_deterministic() {
        let base = TraceSpec::single(
            "m",
            RateCurve::Constant { rate_rps: 100.0, duration_s: 1.0 },
            ArrivalProcess::Poisson,
        );
        // Deterministic writes no `service` key at all, so pre-noise
        // artifacts stay byte-identical.
        assert!(!base.to_json().to_string().contains("service"));
        let noisy = base.clone().with_service(&ServiceModel::LognormalFactor { sigma: 0.7 });
        let back =
            TraceSpec::from_json(&Json::parse(&noisy.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, noisy);
        assert_eq!(back.service_for("m"), ServiceModel::LognormalFactor { sigma: 0.7 });
        assert_eq!(back.service_for("other"), ServiceModel::Deterministic);
        let old = TraceSpec::from_json(&Json::parse(&base.to_json().to_string()).unwrap()).unwrap();
        assert!(old.classes[0].service.is_deterministic());
    }

    #[test]
    fn ramp_and_mix_embed_losslessly() {
        let ramp = RampSpec::parse("1000:4000:1000", 0.5).unwrap();
        let t = TraceSpec::from(&ramp);
        assert_eq!(t.classes.len(), 1);
        assert_eq!(t.classes[0].process, ArrivalProcess::Poisson);
        assert_eq!(t.duration_s().to_bits(), ramp.duration_s().to_bits());
        assert_eq!(t.peak_rps().to_bits(), 4000.0f64.to_bits());
        let RateCurve::Piecewise { rates_rps, phase_s } = &t.classes[0].curve else { panic!() };
        assert_eq!(rates_rps, &ramp.rates_rps);
        assert_eq!(*phase_s, ramp.phase_s);

        let mix = TrafficMix::single("deit_t", ramp);
        let t = TraceSpec::from(&mix);
        assert_eq!(t.classes[0].model, "deit_t");
        assert_eq!(t.models(), vec!["deit_t".to_string()]);
    }
}
