//! Lazy k-way merge of per-class arrival generators into the one
//! `(time, class)` event stream the event loop consumes.
//!
//! [`ArrivalStream::new`] (over a [`TrafficMix`]) moved verbatim from
//! `coordinator::scheduler`; [`ArrivalStream::from_trace`] generalizes it
//! to any [`TraceSpec`] by picking a per-class generator:
//!
//! * ramp-shaped Poisson classes replay on the exact pre-trace
//!   [`ClassArrivals`] path (bit-identical arrivals — the differential
//!   test in `rust/tests/traffic_trace.rs` pins it);
//! * curved Poisson classes (diurnal / flash) use Lewis–Shedler thinning
//!   at the curve's peak-rate majorant;
//! * heavy-tailed classes draw renewal gaps (mean-1 draw over the local
//!   rate), skipping zero-rate spans deterministically.
//!
//! Memory stays O(classes) for any run length, as before.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::sim::device::ArrivalSource;
use crate::traffic::mix::{ClassArrivals, TrafficMix};
use crate::traffic::trace::{ArrivalProcess, RateCurve, TraceSpec};
use crate::util::rng::Rng;

/// Pending head of one class's arrival stream. Keys order by time then
/// class index; times are non-negative finite f64s, whose `to_bits`
/// order equals their numeric order, so a derived lexicographic `Ord`
/// reproduces the materialized sort's
/// `t.total_cmp(..).then(class.cmp(..))` comparator exactly.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PendingArrival {
    t_bits: u64,
    class: usize,
}

/// One class's lazy arrival generator.
enum ClassGen {
    /// Poisson over a piecewise-constant curve: the exact pre-trace
    /// generator, so ramp traffic is bit-identical to the `TrafficMix`
    /// path.
    Exact(ClassArrivals),
    /// Poisson over a smooth curve via Lewis–Shedler thinning: candidate
    /// gaps at the constant majorant rate, each kept with probability
    /// `rate(t) / majorant`. Two uniforms per candidate (gap, then
    /// accept), in that order.
    Thinned { rng: Rng, curve: RateCurve, majorant: f64, t: f64 },
    /// Heavy-tailed renewal: next gap is a mean-1 draw divided by the
    /// local rate, so the class tracks the curve on average while the
    /// gap distribution carries the bursts.
    Renewal { rng: Rng, curve: RateCurve, process: ArrivalProcess, t: f64 },
}

impl ClassGen {
    fn new(curve: &RateCurve, process: ArrivalProcess, rng: Rng) -> ClassGen {
        match process {
            ArrivalProcess::Poisson => match curve.as_ramp() {
                Some(ramp) => ClassGen::Exact(ClassArrivals::new(&ramp, rng)),
                None => {
                    let majorant = curve.peak_rps();
                    // A zero-peak curve offers nothing: start exhausted.
                    let t = if majorant > 0.0 { 0.0 } else { curve.duration_s() };
                    ClassGen::Thinned { rng, curve: curve.clone(), majorant, t }
                }
            },
            p => ClassGen::Renewal { rng, curve: curve.clone(), process: p, t: 0.0 },
        }
    }

    fn next_arrival(&mut self) -> Option<f64> {
        match self {
            ClassGen::Exact(c) => c.next_arrival(),
            ClassGen::Thinned { rng, curve, majorant, t } => {
                let duration = curve.duration_s();
                loop {
                    if *t >= duration {
                        return None;
                    }
                    *t += -(1.0 - rng.f64()).ln() / *majorant;
                    if *t >= duration {
                        return None;
                    }
                    if rng.f64() * *majorant < curve.rate_at(*t) {
                        return Some(*t);
                    }
                }
            }
            ClassGen::Renewal { rng, curve, process, t } => {
                let duration = curve.duration_s();
                loop {
                    if *t >= duration {
                        return None;
                    }
                    let rate = curve.rate_at(*t);
                    if rate <= 0.0 {
                        match advance_past_zero(curve, *t) {
                            Some(t2) => {
                                *t = t2;
                                continue;
                            }
                            None => {
                                *t = duration;
                                return None;
                            }
                        }
                    }
                    *t += process.mean1_gap(rng) / rate;
                    if *t >= duration {
                        return None;
                    }
                    if curve.rate_at(*t) <= 0.0 {
                        continue; // landed in a dead span; skip it above
                    }
                    return Some(*t);
                }
            }
        }
    }
}

/// Deterministic skip to the next instant where `curve` can offer load
/// again, from a zero-rate `t`. Piecewise jumps exactly to the next
/// positive phase; smooth curves step a fixed 1/256 of their natural
/// scale (period / spike width) — deterministic and cheap, and the
/// renewal draw re-checks the landing rate anyway. `None` means the
/// curve stays dead through its end.
fn advance_past_zero(curve: &RateCurve, t: f64) -> Option<f64> {
    match curve {
        RateCurve::Constant { .. } => None, // zero-rate constant is dead forever
        RateCurve::Piecewise { rates_rps, phase_s } => {
            let phase = (t / phase_s) as usize;
            ((phase + 1)..rates_rps.len())
                .find(|&p| rates_rps[p] > 0.0)
                .map(|p| p as f64 * phase_s)
        }
        RateCurve::Diurnal { period_s, .. } => Some(t + period_s / 256.0),
        RateCurve::Flash { at_s, ramp_s, decay_s, .. } => {
            if t < *at_s {
                Some(*at_s) // dead base before the spike: jump to it
            } else {
                Some(t + ramp_s.max(*decay_s) / 256.0)
            }
        }
    }
}

/// Streaming k-way merge of per-class arrival generators: holds one
/// pending arrival per class in a min-heap instead of a materialized,
/// sorted timeline — O(classes) memory for any run length. Each class
/// draws from the same `Rng::split(class_index)` stream regardless of
/// how many classes exist, so adding a class never perturbs another's
/// times, and the merged order is bit-identical to sorting the
/// materialized timeline (same-class ties keep generation order because
/// at most one entry per class is in the heap).
pub struct ArrivalStream {
    classes: Vec<ClassGen>,
    heap: BinaryHeap<Reverse<PendingArrival>>,
}

impl ArrivalStream {
    /// Stream a [`TrafficMix`]: every class on the exact pre-trace
    /// Poisson path (this is `from_trace` restricted to ramps, kept as
    /// the named constructor the pre-trace callers and differential
    /// tests use).
    pub fn new(mix: &TrafficMix, seed: u64) -> ArrivalStream {
        let base = Rng::new(seed);
        let gens = mix
            .classes
            .iter()
            .enumerate()
            .map(|(ci, c)| {
                let class_seed = base.split(ci as u64).next_u64();
                ClassGen::Exact(ClassArrivals::new(&c.ramp, Rng::new(class_seed)))
            })
            .collect();
        ArrivalStream::from_gens(gens)
    }

    /// Stream any [`TraceSpec`]. Class `i` seeds from `split(i)` exactly
    /// as [`ArrivalStream::new`] does, so a ramp-built trace replays the
    /// same arrivals bit for bit.
    pub fn from_trace(trace: &TraceSpec, seed: u64) -> ArrivalStream {
        let base = Rng::new(seed);
        let gens = trace
            .classes
            .iter()
            .enumerate()
            .map(|(ci, c)| {
                let class_seed = base.split(ci as u64).next_u64();
                ClassGen::new(&c.curve, c.process, Rng::new(class_seed))
            })
            .collect();
        ArrivalStream::from_gens(gens)
    }

    fn from_gens(mut classes: Vec<ClassGen>) -> ArrivalStream {
        let mut heap = BinaryHeap::with_capacity(classes.len());
        for (ci, c) in classes.iter_mut().enumerate() {
            if let Some(t) = c.next_arrival() {
                heap.push(Reverse(PendingArrival { t_bits: t.to_bits(), class: ci }));
            }
        }
        ArrivalStream { classes, heap }
    }
}

impl ArrivalSource for ArrivalStream {
    fn peek_s(&self) -> f64 {
        self.heap.peek().map_or(f64::INFINITY, |&Reverse(p)| f64::from_bits(p.t_bits))
    }

    fn pop(&mut self) -> Option<(f64, usize)> {
        let Reverse(p) = self.heap.pop()?;
        // refill from the popped class so the heap again holds every
        // non-exhausted class's head
        if let Some(t) = self.classes[p.class].next_arrival() {
            self.heap.push(Reverse(PendingArrival { t_bits: t.to_bits(), class: p.class }));
        }
        Some((f64::from_bits(p.t_bits), p.class))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::service::ServiceModel;
    use crate::traffic::mix::{RampSpec, TrafficClass};
    use crate::traffic::trace::TraceClass;

    #[test]
    fn streaming_merge_matches_materialize_and_sort() {
        // The pre-streaming TrafficMix::arrivals: materialize every class
        // then stable-sort by (time, class). The k-way heap merge must
        // reproduce it bit for bit, ties included.
        let mix = TrafficMix {
            classes: vec![
                TrafficClass {
                    model: "a".to_string(),
                    ramp: RampSpec::parse("2000:0:1500", 0.3).unwrap(),
                },
                TrafficClass {
                    model: "b".to_string(),
                    ramp: RampSpec::parse("900", 0.7).unwrap(),
                },
                TrafficClass {
                    model: "c".to_string(),
                    ramp: RampSpec::parse("0:4000", 0.25).unwrap(),
                },
            ],
        };
        for seed in [3u64, 99, 0xABCDE] {
            let base = Rng::new(seed);
            let mut want: Vec<(f64, usize)> = Vec::new();
            for (ci, c) in mix.classes.iter().enumerate() {
                let class_seed = base.split(ci as u64).next_u64();
                want.extend(c.ramp.arrivals(class_seed).into_iter().map(|t| (t, ci)));
            }
            want.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let got = mix.arrivals(seed);
            assert_eq!(got.len(), want.len(), "seed {seed}: count");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.0.to_bits(), w.0.to_bits(), "seed {seed}: time bits");
                assert_eq!(g.1, w.1, "seed {seed}: class");
            }
        }
    }

    #[test]
    fn arrival_stream_peek_agrees_with_pop_and_exhausts_to_infinity() {
        let mix = TrafficMix::single("m", RampSpec::parse("1500:800", 0.3).unwrap());
        let mut s = ArrivalStream::new(&mix, 7);
        let mut n = 0usize;
        let mut last = 0.0f64;
        loop {
            let peeked = s.peek_s();
            match s.pop() {
                Some((t, class)) => {
                    assert_eq!(peeked.to_bits(), t.to_bits(), "peek must match pop");
                    assert!(t >= last, "stream went backwards");
                    assert_eq!(class, 0);
                    last = t;
                    n += 1;
                }
                None => {
                    assert_eq!(peeked, f64::INFINITY, "exhausted stream must peek INFINITY");
                    break;
                }
            }
        }
        assert_eq!(n, mix.arrivals(7).len());
    }

    fn drain(trace: &TraceSpec, seed: u64) -> Vec<(f64, usize)> {
        let mut s = ArrivalStream::from_trace(trace, seed);
        let mut out = Vec::new();
        while let Some(a) = s.pop() {
            out.push(a);
        }
        out
    }

    #[test]
    fn thinned_poisson_tracks_a_flash_curve() {
        // Thinning at the majorant: arrivals are sorted, in-span,
        // deterministic per seed, cluster near the spike top, and
        // approximate the curve's integral count.
        let curve = RateCurve::Flash {
            base_rps: 500.0,
            peak_rps: 8000.0,
            at_s: 1.0,
            ramp_s: 0.5,
            decay_s: 0.25,
            duration_s: 3.0,
        };
        let trace = TraceSpec::single("m", curve.clone(), ArrivalProcess::Poisson);
        let a = drain(&trace, 11);
        let b = drain(&trace, 11);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.0.to_bits(), y.0.to_bits());
        }
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0), "sorted");
        assert!(a.iter().all(|&(t, _)| (0.0..3.0).contains(&t)), "in span");
        // integral of the curve: 0.5*3*500 (base-ish) + ramp + decay ≈ 5.1k
        let expect: f64 = (0..3000).map(|i| curve.rate_at(i as f64 * 1e-3) * 1e-3).sum();
        let n = a.len() as f64;
        assert!(
            (n - expect).abs() < 5.0 * expect.sqrt() + 50.0,
            "{n} arrivals vs ~{expect:.0} expected"
        );
        // the spike second must be the densest
        let in_spike = a.iter().filter(|&&(t, _)| (1.0..2.0).contains(&t)).count();
        assert!(in_spike * 2 > a.len(), "spike holds the bulk: {in_spike} of {}", a.len());
    }

    #[test]
    fn heavy_tail_renewal_hits_the_average_but_bursts_harder() {
        // Same constant curve, Poisson vs Pareto gaps: both land near the
        // offered count, but the heavy tail's max gap is far larger at
        // equal rate (the bursts the mean-rate view hides).
        let curve = RateCurve::Constant { rate_rps: 2000.0, duration_s: 4.0 };
        let poisson = drain(&TraceSpec::single("m", curve.clone(), ArrivalProcess::Poisson), 5);
        let pareto = drain(
            &TraceSpec::single("m", curve.clone(), ArrivalProcess::ParetoGaps { alpha: 1.3 }),
            5,
        );
        let logn = drain(
            &TraceSpec::single("m", curve, ArrivalProcess::LognormalGaps { sigma: 2.0 }),
            5,
        );
        for (name, a) in [("poisson", &poisson), ("pareto", &pareto), ("lognormal", &logn)] {
            assert!(a.windows(2).all(|w| w[0].0 <= w[1].0), "{name} sorted");
            assert!(a.iter().all(|&(t, _)| (0.0..4.0).contains(&t)), "{name} in span");
            // 8000 expected; heavy tails wander further from it
            assert!(
                (4000..13000).contains(&a.len()),
                "{name}: {} arrivals far from 8000",
                a.len()
            );
        }
        let max_gap = |a: &[(f64, usize)]| {
            a.windows(2).map(|w| w[1].0 - w[0].0).fold(0.0f64, f64::max)
        };
        assert!(
            max_gap(&pareto) > 3.0 * max_gap(&poisson),
            "pareto max gap {} should dwarf poisson {}",
            max_gap(&pareto),
            max_gap(&poisson)
        );
    }

    #[test]
    fn renewal_skips_dead_piecewise_phases() {
        let curve = RateCurve::Piecewise { rates_rps: vec![0.0, 3000.0, 0.0, 1000.0], phase_s: 0.25 };
        let trace =
            TraceSpec::single("m", curve, ArrivalProcess::LognormalGaps { sigma: 1.0 });
        let a = drain(&trace, 9);
        assert!(!a.is_empty());
        for &(t, _) in &a {
            let phase = (t / 0.25) as usize;
            assert!(phase == 1 || phase == 3, "arrival {t} in a zero-rate phase");
        }
    }

    #[test]
    fn multi_class_trace_interleaves_and_keeps_class_streams_independent() {
        let flash = RateCurve::Flash {
            base_rps: 1000.0,
            peak_rps: 4000.0,
            at_s: 0.5,
            ramp_s: 0.2,
            decay_s: 0.2,
            duration_s: 2.0,
        };
        let ramp = RateCurve::Piecewise { rates_rps: vec![1500.0, 500.0], phase_s: 1.0 };
        let two = TraceSpec::new(vec![
            TraceClass {
                model: "a".into(),
                curve: flash.clone(),
                process: ArrivalProcess::Poisson,
                service: ServiceModel::Deterministic,
            },
            TraceClass {
                model: "b".into(),
                curve: ramp,
                process: ArrivalProcess::ParetoGaps { alpha: 2.0 },
                service: ServiceModel::Deterministic,
            },
        ])
        .unwrap();
        let merged = drain(&two, 21);
        assert!(merged.windows(2).all(|w| w[0].0 <= w[1].0));
        assert!(merged.iter().any(|&(_, c)| c == 0) && merged.iter().any(|&(_, c)| c == 1));
        // class 0 alone draws the same times: split streams are independent
        let solo = drain(&TraceSpec::single("a", flash, ArrivalProcess::Poisson), 21);
        let class0: Vec<f64> =
            merged.iter().filter(|&&(_, c)| c == 0).map(|&(t, _)| t).collect();
        assert_eq!(class0.len(), solo.len());
        for (g, w) in class0.iter().zip(solo.iter().map(|&(t, _)| t)) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }
}
