//! Hardware platform descriptions (paper Tables 1 & 4, §6 Q1).
//!
//! Each platform is a bag of resource/rate constants consumed by the
//! analytical model and the DSE. The VCK190 numbers come straight from the
//! paper (102.4 INT8 TOPS = 400 AIE x 128 MAC x 2 op @ 1 GHz; 25.6 GB/s
//! DDR; PL @ 230 MHz); FPGA fabric totals are the VC1902 device counts.

/// A Versal-ACAP-like platform: AIE compute array + PL fabric + NoC + DDR.
#[derive(Clone, Debug)]
pub struct Platform {
    pub name: &'static str,
    /// Number of AIE vector cores usable (paper deploys up to 394/400).
    pub aie_total: u64,
    /// INT8 MACs per AIE per cycle (128 on AIE1 => 102.4 TOPS total).
    pub macs_per_aie_cycle: u64,
    /// AIE clock (GHz).
    pub aie_ghz: f64,
    /// AIE local data memory per tile (bytes) — paper's 32 KB constraint.
    pub aie_local_mem: u64,
    /// PL fabric clock (MHz).
    pub pl_mhz: f64,
    /// Total PLIO stream channels between PL and AIE array.
    pub plio_total: u64,
    /// Bytes per PLIO per PL cycle (128-bit streams).
    pub plio_bytes_per_cycle: u64,
    /// PL fabric resources (VC1902 device totals).
    pub bram_total: u64,
    pub uram_total: u64,
    pub dsp_total: u64,
    pub lut_total: u64,
    pub reg_total: u64,
    /// Off-chip bandwidth (GB/s) — Table 1.
    pub ddr_gbs: f64,
    /// Power model: static watts + max dynamic watts at full utilization.
    pub static_w: f64,
    pub dyn_w: f64,
    /// Board TDP (Table 4) — reporting only.
    pub tdp_w: f64,
}

impl Platform {
    /// Peak INT8 TOPS (Table 1: VCK190 = 102.4).
    pub fn peak_int8_tops(&self) -> f64 {
        self.aie_total as f64 * self.macs_per_aie_cycle as f64 * 2.0 * self.aie_ghz
            / 1e3
    }

    /// Aggregate PL<->AIE stream bandwidth (GB/s) across all PLIOs.
    pub fn plio_total_gbs(&self) -> f64 {
        self.plio_total as f64 * self.plio_bytes_per_cycle as f64 * self.pl_mhz / 1e3
    }
}

/// AMD Versal ACAP VCK190 (the paper's implementation target).
pub fn vck190() -> Platform {
    Platform {
        name: "vck190",
        aie_total: 400,
        macs_per_aie_cycle: 128,
        aie_ghz: 1.0,
        aie_local_mem: 32 * 1024,
        pl_mhz: 230.0,
        plio_total: 234,
        plio_bytes_per_cycle: 16,
        bram_total: 967,
        uram_total: 463,
        dsp_total: 1968,
        lut_total: 899_840,
        reg_total: 1_799_680,
        ddr_gbs: 25.6,
        // Board power at inference measured ~45-60 W in the paper's
        // energy-efficiency numbers (26.7 TOPS / 453 GOPS/W ~ 59 W).
        static_w: 40.0,
        dyn_w: 72.0,
        tdp_w: 180.0,
    }
}

/// Hypothetical VCK190 with 102 GB/s off-chip BW (paper §6: 0.41 ms DeiT-T).
pub fn vck190_hbm() -> Platform {
    Platform { name: "vck190_hbm", ddr_gbs: 102.4, ..vck190() }
}

/// Intel Stratix 10 NX modeled as an SSR target (paper §6 Q1): 143 INT8
/// TOPS of AI tensor blocks, 16 MB on-chip, 512 GB/s HBM. We express the
/// tensor-block fabric in "AIE-equivalent" units so the same Eq. 1/2 model
/// applies: 3960 tensor blocks -> 560 equivalent cores x 128 MACs @ 1 GHz
/// = 143.4 TOPS.
pub fn stratix10nx() -> Platform {
    Platform {
        name: "stratix10nx",
        aie_total: 560,
        macs_per_aie_cycle: 128,
        aie_ghz: 1.0,
        aie_local_mem: 20 * 1024,
        pl_mhz: 300.0,
        plio_total: 320,
        plio_bytes_per_cycle: 16,
        bram_total: 6847, // M20K blocks
        uram_total: 0,
        dsp_total: 3960,
        lut_total: 1_624_000,
        reg_total: 3_248_000,
        ddr_gbs: 512.0,
        static_w: 30.0,
        dyn_w: 70.0,
        tdp_w: 225.0,
    }
}

/// GPU / FPGA comparison boards (Table 4) — used by `baselines`, not by the
/// SSR DSE (they are not spatially composable in our model).
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    pub peak_int8_tops: f64,
    pub peak_fp32_tflops: f64,
    pub mem_gbs: f64,
    pub tdp_w: f64,
    pub static_w: f64,
    pub dyn_w: f64,
}

/// Nvidia A10G (Table 1: 140 INT8 TOPS, 600 GB/s; TDP 300 W — but the
/// paper's measured GOPS/W implies ~120-210 W draw at inference).
pub fn a10g() -> GpuSpec {
    GpuSpec {
        name: "a10g",
        peak_int8_tops: 140.0,
        peak_fp32_tflops: 35.0,
        mem_gbs: 600.0,
        tdp_w: 300.0,
        static_w: 60.0,
        dyn_w: 150.0,
    }
}

/// HeatViT-style monolithic FPGA accelerators (Table 4/5 baselines).
#[derive(Clone, Debug)]
pub struct FpgaSpec {
    pub name: &'static str,
    pub dsp_total: u64,
    pub freq_mhz: f64,
    /// INT8 MACs per DSP per cycle for the HeatViT engine.
    pub macs_per_dsp_cycle: f64,
    pub tdp_w: f64,
    pub static_w: f64,
    pub dyn_w: f64,
}

impl FpgaSpec {
    /// Peak INT8 TOPS of the DSP array (2 ops per MAC).
    pub fn peak_int8_tops(&self) -> f64 {
        self.dsp_total as f64 * self.macs_per_dsp_cycle * 2.0 * self.freq_mhz * 1e6 / 1e12
    }
}

pub fn zcu102() -> FpgaSpec {
    FpgaSpec {
        name: "zcu102",
        dsp_total: 2520,
        freq_mhz: 250.0,
        macs_per_dsp_cycle: 1.0,
        tdp_w: 90.0,
        static_w: 6.5,
        dyn_w: 9.0,
    }
}

pub fn u250() -> FpgaSpec {
    FpgaSpec {
        name: "u250",
        dsp_total: 12_288,
        freq_mhz: 250.0,
        macs_per_dsp_cycle: 1.0,
        tdp_w: 225.0,
        static_w: 60.0,
        dyn_w: 100.0,
    }
}

/// Any named board the cluster layer can put in a fleet: Versal-class SSR
/// platforms (full 8-class hybrid design space) or monolithic FPGA
/// baselines (HeatViT-style engines, sequential-only). Unifies name lookup
/// and the power-model constants the provisioner needs.
#[derive(Clone, Debug)]
pub enum AnyPlatform {
    Versal(Platform),
    Fpga(FpgaSpec),
}

impl AnyPlatform {
    pub fn name(&self) -> &'static str {
        match self {
            AnyPlatform::Versal(p) => p.name,
            AnyPlatform::Fpga(f) => f.name,
        }
    }

    pub fn static_w(&self) -> f64 {
        match self {
            AnyPlatform::Versal(p) => p.static_w,
            AnyPlatform::Fpga(f) => f.static_w,
        }
    }

    pub fn dyn_w(&self) -> f64 {
        match self {
            AnyPlatform::Versal(p) => p.dyn_w,
            AnyPlatform::Fpga(f) => f.dyn_w,
        }
    }

    pub fn peak_int8_tops(&self) -> f64 {
        match self {
            AnyPlatform::Versal(p) => p.peak_int8_tops(),
            AnyPlatform::Fpga(f) => f.peak_int8_tops(),
        }
    }
}

/// Every board name `by_name` resolves, in lookup order. Diagnostics quote
/// this list so an unknown-platform error names the valid alternatives.
pub const KNOWN_BOARDS: [&str; 5] = ["vck190", "vck190_hbm", "stratix10nx", "zcu102", "u250"];

/// Board lookup for fleet specs (`FleetSpec` serializes platform by name).
pub fn by_name(name: &str) -> Option<AnyPlatform> {
    match name {
        "vck190" => Some(AnyPlatform::Versal(vck190())),
        "vck190_hbm" => Some(AnyPlatform::Versal(vck190_hbm())),
        "stratix10nx" => Some(AnyPlatform::Versal(stratix10nx())),
        "zcu102" => Some(AnyPlatform::Fpga(zcu102())),
        "u250" => Some(AnyPlatform::Fpga(u250())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_covers_every_board_and_rejects_unknown() {
        for name in ["vck190", "vck190_hbm", "stratix10nx", "zcu102", "u250"] {
            let p = by_name(name).unwrap();
            assert_eq!(p.name(), name);
            assert!(p.peak_int8_tops() > 0.0);
            assert!(p.static_w() > 0.0 && p.dyn_w() > 0.0);
        }
        assert!(by_name("tpu_v9").is_none());
    }

    #[test]
    fn fpga_peak_matches_heatvit_formula() {
        // 2520 DSPs x 1 MAC x 2 ops @ 250 MHz = 1.26 TOPS
        assert!((zcu102().peak_int8_tops() - 1.26).abs() < 1e-9);
    }

    #[test]
    fn vck190_peak_matches_table1() {
        let p = vck190();
        assert!((p.peak_int8_tops() - 102.4).abs() < 1e-9);
    }

    #[test]
    fn stratix_peak_close_to_143_tops() {
        let p = stratix10nx();
        assert!((p.peak_int8_tops() - 143.0).abs() / 143.0 < 0.01);
    }

    #[test]
    fn a10g_matches_table1() {
        let g = a10g();
        assert_eq!(g.peak_int8_tops, 140.0);
        assert_eq!(g.peak_fp32_tflops, 35.0);
        assert_eq!(g.mem_gbs, 600.0);
    }

    #[test]
    fn plio_bandwidth_positive() {
        let p = vck190();
        let gbs = p.plio_total_gbs();
        assert!(gbs > 100.0 && gbs < 2000.0, "plio {gbs} GB/s");
    }

    #[test]
    fn hbm_variant_only_changes_bw() {
        let a = vck190();
        let b = vck190_hbm();
        assert_eq!(a.aie_total, b.aie_total);
        assert!(b.ddr_gbs > a.ddr_gbs);
    }
}
