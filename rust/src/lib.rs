//! # SSR — Spatial Sequential Hybrid Architecture (FPGA '24) reproduction
//!
//! A full-system reproduction of Zhuang et al., *SSR: Spatial Sequential
//! Hybrid Architecture for Latency Throughput Tradeoff in Transformer
//! Acceleration* (FPGA '24), as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the SSR framework: application graph IR
//!   ([`graph`]), platform models ([`arch`]), the paper's analytical cost
//!   model ([`analytical`]), an event-driven pipeline simulator ([`sim`]),
//!   the evolutionary design-space exploration ([`dse`]), the shared
//!   ExecutionPlan IR tying search, simulation, and serving to one mapping
//!   representation ([`plan`]), comparison
//!   baselines ([`baselines`]), a PJRT serving runtime ([`runtime`] +
//!   [`coordinator`]), a heterogeneous multi-device fleet layer — specs,
//!   routing, fleet simulation, provisioning — ([`cluster`]), and report
//!   generators for every paper table/figure ([`report`]).
//! * **L2/L1 (python/, build-time only)** — the DeiT-style transformer in
//!   JAX calling Pallas kernels, AOT-lowered to the HLO text artifacts the
//!   runtime serves.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod analytical;
pub mod arch;
pub mod baselines;
pub mod bench;
pub mod cluster;
pub mod coordinator;
pub mod dse;
pub mod graph;
pub mod plan;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
