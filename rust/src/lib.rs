//! # SSR — Spatial Sequential Hybrid Architecture (FPGA '24) reproduction
//!
//! A full-system reproduction of Zhuang et al., *SSR: Spatial Sequential
//! Hybrid Architecture for Latency Throughput Tradeoff in Transformer
//! Acceleration* (FPGA '24), as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the SSR framework: application graph IR
//!   ([`graph`]), platform models ([`arch`]), the paper's analytical cost
//!   model ([`analytical`]), an event-driven pipeline simulator ([`sim`]),
//!   the evolutionary design-space exploration ([`dse`]), the shared
//!   ExecutionPlan IR tying search, simulation, and serving to one mapping
//!   representation ([`plan`]), comparison
//!   baselines ([`baselines`]), a PJRT serving runtime ([`runtime`] +
//!   [`coordinator`]), a heterogeneous multi-device fleet layer — specs,
//!   routing, fleet simulation, provisioning, and a closed-loop
//!   autoscaling controller with failure injection and hitless rolling
//!   front swaps — ([`cluster`]), the unified workload-trace API every
//!   traffic consumer speaks ([`traffic`]), a deterministic observability
//!   layer — structured event tracing, metrics, SLO burn-rate monitoring
//!   — ([`obs`]), a static artifact verifier every CLI deserialization
//!   boundary routes through ([`check`]), and report generators for
//!   every paper table/figure ([`report`]).
//! * **L2/L1 (python/, build-time only)** — the DeiT-style transformer in
//!   JAX calling Pallas kernels, AOT-lowered to the HLO text artifacts the
//!   runtime serves.
//!
//! See ARCHITECTURE.md for the module map and the conventions the
//! subsystems share (event-loop tie order, `{committed, draining}` plan
//! state, device lifecycle), and README.md for the CLI reference.

pub mod analytical;
pub mod arch;
pub mod baselines;
pub mod bench;
pub mod check;
pub mod cluster;
pub mod coordinator;
pub mod dse;
pub mod graph;
pub mod obs;
pub mod plan;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod traffic;
pub mod util;
