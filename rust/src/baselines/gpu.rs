//! TensorRT-on-A10G analytical baseline, rebuilt from the paper's own
//! measurements (Fig. 3 kernel breakdown + Table 5 batch sweep).
//!
//! Structure: a ViT inference is ~170 kernel launches (the paper's Fig. 3
//! taxonomy: MM/BMM/patch-embed, Softmax/GELU/LayerNorm on CUDA cores,
//! Transpose, Reformat). Small-batch ViT kernels are launch/occupancy-floor
//! bound (the `min_kernel_us` floor reproduces the paper's ~0.6 ms
//! batch-independent intercept); the marginal per-image cost comes from the
//! effective MM throughput, which the paper measures at 18 TOPS (13% of the
//! 140 TOPS peak) for DeiT-T at batch 6, growing mildly with layer size.

use crate::arch::GpuSpec;
use crate::graph::{Graph, HceKind};

/// Kernel-category time breakdown (seconds) — Fig. 3's pie, regenerable.
#[derive(Clone, Debug, Default)]
pub struct GpuBreakdown {
    pub mm_s: f64,
    pub softmax_s: f64,
    pub layernorm_s: f64,
    pub gelu_s: f64,
    pub transpose_s: f64,
    pub reformat_s: f64,
    pub launch_floor_s: f64,
}

impl GpuBreakdown {
    pub fn total_s(&self) -> f64 {
        self.mm_s
            + self.softmax_s
            + self.layernorm_s
            + self.gelu_s
            + self.transpose_s
            + self.reformat_s
            + self.launch_floor_s
    }

    /// Nonlinear share of total (paper: ~28% for DeiT-T b6).
    pub fn nonlinear_share(&self) -> f64 {
        (self.softmax_s + self.layernorm_s + self.gelu_s) / self.total_s()
    }
}

/// Calibration for the GPU model.
#[derive(Clone, Copy, Debug)]
pub struct GpuCalib {
    /// Effective MM TOPS for a ~1.25 GMAC ViT at batch >= 6 (Fig. 3: 18).
    pub mm_tops_ref: f64,
    /// MACs of the reference model the 18 TOPS was measured on.
    pub macs_ref: f64,
    /// Utilization growth exponent with model size.
    pub size_exp: f64,
    /// Utilization ramp with batch: util(b) = b / (b + batch_half).
    pub batch_half: f64,
    /// Per-kernel launch + occupancy floor (us).
    pub min_kernel_us: f64,
    /// CUDA-core elementwise/nonlinear effective bandwidth (GB/s, fp32).
    pub elem_gbs: f64,
}

impl Default for GpuCalib {
    fn default() -> Self {
        GpuCalib {
            mm_tops_ref: 18.0,
            macs_ref: 1.25e9,
            size_exp: 0.7,
            batch_half: 0.35,
            min_kernel_us: 3.2,
            elem_gbs: 450.0,
        }
    }
}

/// Effective MM throughput (TOPS) for a model of `macs` at `batch`.
pub fn mm_eff_tops(gpu: &GpuSpec, cal: &GpuCalib, macs: f64, batch: usize) -> f64 {
    let size = (macs / cal.macs_ref).powf(cal.size_exp);
    let ramp = batch as f64 / (batch as f64 + cal.batch_half);
    let ramp_ref = 6.0 / (6.0 + cal.batch_half);
    (cal.mm_tops_ref * size * ramp / ramp_ref).min(gpu.peak_int8_tops)
}

/// Full kernel-level breakdown for `graph` at `batch` (Fig. 3 regenerator).
///
/// Every kernel pays `max(launch/occupancy floor, data time)`: ViT layers
/// are tiny, so at small batch almost everything sits on the floor — that
/// is exactly the paper's observation that nonlinear kernels are <1% of
/// the FLOPs but ~28% of the time.
pub fn breakdown(gpu: &GpuSpec, cal: &GpuCalib, graph: &Graph, batch: usize) -> GpuBreakdown {
    let b = batch as f64;
    let floor = cal.min_kernel_us * 1e-6;
    let mut out = GpuBreakdown::default();

    // MM/BMM/patch-embed: effective-TOPS bound, floored per kernel launch.
    let mm_ops = graph.ops_per_image() as f64 * b;
    let mm_kernels = graph.nodes.len() as f64;
    out.mm_s = (mm_ops
        / (mm_eff_tops(gpu, cal, graph.macs_per_image as f64, batch) * 1e12))
        .max(mm_kernels * floor);

    // Non-MM kernels: CUDA-core bandwidth bound (fp32 in TensorRT's
    // nonlinear stages — hence the Reformat kernels around them), floored
    // per kernel.
    for n in &graph.nodes {
        for h in &n.hce {
            if h.kind == HceKind::Add {
                continue; // fused into the producing MM by TensorRT
            }
            let bytes = h.elems as f64 * 4.0 * b;
            let t = (bytes / (cal.elem_gbs * 1e9)).max(floor);
            match h.kind {
                HceKind::Softmax => out.softmax_s += t,
                HceKind::LayerNorm => out.layernorm_s += t,
                HceKind::Gelu => out.gelu_s += t,
                HceKind::Transpose => out.transpose_s += t,
                HceKind::Reformat => out.reformat_s += t,
                HceKind::Add => unreachable!(),
            }
        }
    }
    out.launch_floor_s = 0.0; // folded into the per-kernel floors above
    out
}

/// End-to-end latency (seconds).
pub fn latency_s(gpu: &GpuSpec, cal: &GpuCalib, graph: &Graph, batch: usize) -> f64 {
    breakdown(gpu, cal, graph, batch).total_s()
}

/// Effective throughput (TOPS).
pub fn tops(gpu: &GpuSpec, cal: &GpuCalib, graph: &Graph, batch: usize) -> f64 {
    let ops = (batch as u64 * graph.ops_per_image()) as f64;
    ops / latency_s(gpu, cal, graph, batch) / 1e12
}

/// GPU power model: affine in achieved throughput, fit to the paper's
/// measured GOPS/W at b=1 and b=6 (P ~ 79 W idle-ish + 12.9 W per TOPS).
pub fn power_w(t: f64) -> f64 {
    78.8 + 12.9 * t
}

/// Energy efficiency (GOPS/W).
pub fn gops_per_w(gpu: &GpuSpec, cal: &GpuCalib, graph: &Graph, batch: usize) -> f64 {
    let t = tops(gpu, cal, graph, batch);
    t * 1e3 / power_w(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::a10g;
    use crate::graph::{vit_graph, DEIT_T};
    use crate::util::stats::rel_err;

    #[test]
    fn deit_t_latencies_near_table5() {
        // Table 5 A10G DeiT-T: 0.76 / 1.03 / 1.43 ms at b=1/3/6.
        let g = vit_graph(&DEIT_T);
        let gpu = a10g();
        let cal = GpuCalib::default();
        for (b, paper_ms) in [(1, 0.76), (3, 1.03), (6, 1.43)] {
            let got = latency_s(&gpu, &cal, &g, b) * 1e3;
            assert!(
                rel_err(got, paper_ms) < 0.25,
                "b={b}: got {got:.3} ms vs paper {paper_ms}"
            );
        }
    }

    #[test]
    fn mm_utilization_near_13_percent_at_b6() {
        // Fig. 3 obs 1: MM effective throughput ~13% of the 140 TOPS peak.
        let gpu = a10g();
        let cal = GpuCalib::default();
        let eff = mm_eff_tops(&gpu, &cal, 1.25e9, 6);
        let share = eff / gpu.peak_int8_tops;
        assert!((0.10..0.16).contains(&share), "share={share}");
    }

    #[test]
    fn nonlinear_share_substantial() {
        // Fig. 3 obs 2: nonlinear kernels ~28% of total time (we accept a
        // broad band — the share depends on the floor attribution).
        let g = vit_graph(&DEIT_T);
        let bd = breakdown(&a10g(), &GpuCalib::default(), &g, 6);
        let s = bd.nonlinear_share();
        assert!((0.05..0.45).contains(&s), "nonlinear share {s}");
    }

    #[test]
    fn latency_grows_sublinearly_with_batch() {
        // The floor makes small batches inefficient: lat(6) << 6 x lat(1).
        let g = vit_graph(&DEIT_T);
        let gpu = a10g();
        let cal = GpuCalib::default();
        let l1 = latency_s(&gpu, &cal, &g, 1);
        let l6 = latency_s(&gpu, &cal, &g, 6);
        assert!(l6 < 3.0 * l1, "l1={l1} l6={l6}");
        assert!(l6 > l1);
    }

    #[test]
    fn throughput_increases_with_batch() {
        let g = vit_graph(&DEIT_T);
        let gpu = a10g();
        let cal = GpuCalib::default();
        assert!(tops(&gpu, &cal, &g, 6) > tops(&gpu, &cal, &g, 1));
    }

    #[test]
    fn b1_throughput_near_paper() {
        // Table 5: 3.19 TOPS at batch 1.
        let g = vit_graph(&DEIT_T);
        let got = tops(&a10g(), &GpuCalib::default(), &g, 1);
        assert!(rel_err(got, 3.19) < 0.35, "got {got}");
    }
}
