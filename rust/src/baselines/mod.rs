//! Comparison baselines (paper Tables 4-5, Fig. 3, §5.2.6).
//!
//! * [`gpu`]     — TensorRT-on-A10G kernel-level model, calibrated to the
//!   paper's own Fig. 3 profile (the paper measured these; we rebuild the
//!   batch-sweep behaviour from the published breakdown).
//! * [`heatvit`] — HeatViT monolithic FPGA accelerator model on ZCU102 and
//!   U250 (Table 5's FPGA columns).
//! * [`charm`]   — the CHARM-like no-forwarding ACAP baseline (§5.2.6's
//!   12 ms starting point): SSR with all three optimizations disabled.

pub mod charm;
pub mod gpu;
pub mod heatvit;
