//! HeatViT monolithic-FPGA baseline (paper Table 5's ZCU102/U250 columns).
//!
//! HeatViT runs a single generic engine sequentially over all layers; its
//! achievable throughput is a fixed fraction of the DSP-array peak
//! (shape mismatch + memory stalls), and there is a small per-inference
//! setup intercept. Calibrated to the paper's measured DeiT-T latencies
//! (ZCU102: 5.50/15.14/29.79 ms; U250: 2.23/5.60/10.66 ms at b=1/3/6) and
//! scaled to other models by MACs.

use crate::arch::FpgaSpec;
use crate::graph::Graph;

/// Calibration per board.
#[derive(Clone, Copy, Debug)]
pub struct FpgaCalib {
    /// Fraction of DSP peak the engine sustains on ViT layers.
    pub util: f64,
    /// Per-inference setup intercept (ms).
    pub intercept_ms: f64,
}

/// Calibrated constants for the two boards in the paper.
pub fn calib_for(board: &FpgaSpec) -> FpgaCalib {
    match board.name {
        "zcu102" => FpgaCalib { util: 0.41, intercept_ms: 0.65 },
        "u250" => FpgaCalib { util: 0.24, intercept_ms: 0.55 },
        _ => FpgaCalib { util: 0.3, intercept_ms: 0.6 },
    }
}

/// Peak INT8 TOPS of the DSP array.
pub fn peak_tops(board: &FpgaSpec) -> f64 {
    board.peak_int8_tops()
}

/// Sustained effective TOPS.
pub fn eff_tops(board: &FpgaSpec, cal: &FpgaCalib) -> f64 {
    peak_tops(board) * cal.util
}

/// End-to-end latency (seconds) at `batch`. Sequential engine: linear in
/// batch plus the setup intercept.
pub fn latency_s(board: &FpgaSpec, cal: &FpgaCalib, graph: &Graph, batch: usize) -> f64 {
    let ops = (batch as u64 * graph.ops_per_image()) as f64;
    cal.intercept_ms * 1e-3 + ops / (eff_tops(board, cal) * 1e12)
}

pub fn tops(board: &FpgaSpec, cal: &FpgaCalib, graph: &Graph, batch: usize) -> f64 {
    let ops = (batch as u64 * graph.ops_per_image()) as f64;
    ops / latency_s(board, cal, graph, batch) / 1e12
}

pub fn gops_per_w(board: &FpgaSpec, cal: &FpgaCalib, graph: &Graph, batch: usize) -> f64 {
    crate::analytical::energy::gops_per_w_generic(
        board.static_w,
        board.dyn_w,
        peak_tops(board),
        tops(board, cal, graph, batch),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{u250, zcu102};
    use crate::graph::{vit_graph, DEIT_T, DEIT_T_256};
    use crate::util::stats::rel_err;

    #[test]
    fn zcu102_deit_t_near_table5() {
        let g = vit_graph(&DEIT_T);
        let b = zcu102();
        let cal = calib_for(&b);
        for (batch, paper_ms) in [(1, 5.50), (3, 15.14), (6, 29.79)] {
            let got = latency_s(&b, &cal, &g, batch) * 1e3;
            assert!(
                rel_err(got, paper_ms) < 0.25,
                "b={batch}: {got:.2} vs {paper_ms}"
            );
        }
    }

    #[test]
    fn u250_deit_t_near_table5() {
        let g = vit_graph(&DEIT_T);
        let b = u250();
        let cal = calib_for(&b);
        for (batch, paper_ms) in [(1, 2.23), (3, 5.60), (6, 10.66)] {
            let got = latency_s(&b, &cal, &g, batch) * 1e3;
            assert!(
                rel_err(got, paper_ms) < 0.25,
                "b={batch}: {got:.2} vs {paper_ms}"
            );
        }
    }

    #[test]
    fn scales_with_model_size() {
        // DeiT-T-256 (2.1 GMACs) should be ~1.7x DeiT-T (1.25) per image.
        let b = zcu102();
        let cal = calib_for(&b);
        let small = latency_s(&b, &cal, &vit_graph(&DEIT_T), 6);
        let big = latency_s(&b, &cal, &vit_graph(&DEIT_T_256), 6);
        let ratio = big / small;
        assert!((1.4..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn throughput_saturates_with_batch() {
        let b = zcu102();
        let cal = calib_for(&b);
        let g = vit_graph(&DEIT_T);
        let t1 = tops(&b, &cal, &g, 1);
        let t6 = tops(&b, &cal, &g, 6);
        // Table 5: 0.44 -> 0.49 TOPS (mild growth as intercept amortizes).
        assert!(t6 > t1 && t6 < 1.3 * t1, "{t1} -> {t6}");
    }

    #[test]
    fn u250_faster_but_less_efficient_than_zcu102() {
        // Table 5: U250 has ~3x the throughput but ~1/3 the GOPS/W.
        let g = vit_graph(&DEIT_T);
        let z = zcu102();
        let u = u250();
        let tz = tops(&z, &calib_for(&z), &g, 6);
        let tu = tops(&u, &calib_for(&u), &g, 6);
        assert!(tu > 2.0 * tz);
        let ez = gops_per_w(&z, &calib_for(&z), &g, 6);
        let eu = gops_per_w(&u, &calib_for(&u), &g, 6);
        assert!(ez > 2.0 * eu, "zcu {ez} vs u250 {eu}");
    }
}
