//! CHARM-like no-forwarding baseline (paper Sec. 2 + §5.2.6).
//!
//! CHARM composes heterogeneous accelerators on the same ACAP but moves
//! every inter-accelerator tensor through off-chip DDR and has no
//! fine-grained MM/non-MM pipeline. In SSR terms that is exactly
//! `Features::baseline()` — the 12 ms DeiT-T starting point of the paper's
//! step-by-step analysis (§5.2.6).

use crate::analytical::{Calib, Features};
use crate::arch::Platform;
use crate::dse::eval::{build_design, Evaluated};
use crate::dse::Assignment;
use crate::graph::Graph;

/// Build the CHARM-like baseline design (monolithic acc, DDR round-trips,
/// no fine-grained pipeline).
pub fn baseline_design(
    platform: &Platform,
    calib: &Calib,
    graph: &Graph,
) -> Option<Evaluated> {
    build_design(
        platform,
        calib,
        graph,
        &Assignment::sequential(),
        Features::baseline(),
        false,
    )
}

/// §5.2.6 step configurations, in order: baseline, +forwarding, +spatial,
/// +pipeline (each step keeps the previous ones).
pub fn step_features() -> [(&'static str, Features, Assignment); 4] {
    [
        ("baseline (CHARM-like)", Features::baseline(), Assignment::sequential()),
        (
            "+ on-chip forwarding",
            Features { on_chip_forwarding: true, ..Features::baseline() },
            Assignment::sequential(),
        ),
        (
            "+ spatial accelerators",
            Features {
                on_chip_forwarding: true,
                spatial: true,
                fine_grained_pipeline: false,
            },
            Assignment::spatial(),
        ),
        ("+ fine-grained pipeline", Features::all(), Assignment::spatial()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck190;
    use crate::graph::{vit_graph, DEIT_T};

    #[test]
    fn baseline_much_slower_than_full_ssr() {
        // §5.2.6: 12 ms baseline vs 0.54 ms SSR at batch 6 (22x). We accept
        // a broad band; the bench reports the exact measured factors.
        let p = vck190();
        let cal = Calib::default();
        let g = vit_graph(&DEIT_T);
        let base = baseline_design(&p, &cal, &g).unwrap().evaluate(&p, &g, 6);
        let ssr = build_design(&p, &cal, &g, &Assignment::spatial(), Features::all(), true)
            .unwrap()
            .evaluate(&p, &g, 6);
        let factor = base.latency_s / ssr.latency_s;
        assert!(factor > 5.0, "total step-opt factor only {factor:.1}x");
    }

    #[test]
    fn each_step_improves_latency() {
        let p = vck190();
        let cal = Calib::default();
        let g = vit_graph(&DEIT_T);
        let mut prev = f64::INFINITY;
        for (name, feats, assign) in step_features() {
            let ev = build_design(&p, &cal, &g, &assign, feats, true).unwrap();
            let lat = ev.evaluate(&p, &g, 6).latency_s;
            assert!(lat < prev, "step '{name}' regressed: {lat} vs {prev}");
            prev = lat;
        }
    }
}
