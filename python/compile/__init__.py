"""Build-time-only python package: L2 JAX model + L1 Pallas kernels + AOT.

Nothing in this package is imported at serve time; ``compile.aot`` emits HLO
text + weight binaries into ``artifacts/`` once, and the rust coordinator is
self-contained afterwards.
"""
