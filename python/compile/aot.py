"""AOT compile path: lower L2/L1 to HLO *text* artifacts + weight binaries.

Run once via ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Outputs (all consumed by the rust runtime, never by python at serve time):

    artifacts/
      manifest.json               executable + weight index (see below)
      <model>_<stage>_b<N>.hlo.txt   HLO text per stage executable
      smoke.hlo.txt / smoke_pallas.hlo.txt   tiny fixtures for rust tests
      weights/<model>/wNNNN.bin   f32 little-endian flat weight blobs

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. Lowered with ``return_tuple=True``; rust unwraps with
``to_tuple1()``.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(x) -> jax.ShapeDtypeStruct:
    if isinstance(x, jax.ShapeDtypeStruct):
        return x
    return jax.ShapeDtypeStruct(np.shape(x), jnp.asarray(x).dtype)


def _flatten_named(tree) -> List[tuple]:
    """Deterministic (name, leaf) list; names like 'blocks/3/wqkv'."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, leaf))
    return out


class ArtifactWriter:
    """Accumulates weights + executables and writes the manifest."""

    def __init__(self, out_dir: str):
        self.out = out_dir
        self.weights: List[Dict[str, Any]] = []
        self.executables: List[Dict[str, Any]] = []
        self.models: Dict[str, Any] = {}
        self._weight_ids: Dict[int, int] = {}  # id(array) -> weight id
        os.makedirs(out_dir, exist_ok=True)

    def add_weight(self, model: str, name: str, arr: jax.Array) -> int:
        key = id(arr)
        if key in self._weight_ids:
            return self._weight_ids[key]
        wid = len(self.weights)
        rel = f"weights/{model}/w{wid:04d}.bin"
        path = os.path.join(self.out, rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        host = np.asarray(arr, dtype=np.float32)
        host.tofile(path)
        self.weights.append(
            {"id": wid, "name": f"{model}/{name}", "shape": list(host.shape), "file": rel}
        )
        self._weight_ids[key] = wid
        return wid

    def add_executable(
        self,
        *,
        name: str,
        fn,
        args: Sequence[Dict[str, Any]],
        arrays: Sequence[Any],
        outputs_of,
        extra: Dict[str, Any] | None = None,
    ) -> None:
        """Lower ``fn(*arrays-shaped-args)`` and record the arg schema.

        ``args`` is the manifest-facing schema (kind=weight/input/block_weight),
        ``arrays`` the concrete example values/specs used for lowering.
        """
        specs = [_spec(a) for a in arrays]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        hlo_rel = f"{name}.hlo.txt"
        with open(os.path.join(self.out, hlo_rel), "w") as f:
            f.write(text)
        out_shapes = [list(s.shape) for s in jax.tree_util.tree_leaves(
            jax.eval_shape(fn, *specs))]
        entry = {
            "name": name,
            "hlo": hlo_rel,
            "args": list(args),
            "outputs": out_shapes,
        }
        if extra:
            entry.update(extra)
        self.executables.append(entry)
        print(f"  wrote {hlo_rel}  ({len(text)} chars, {len(specs)} args)")

    def finish(self) -> None:
        manifest = {
            "format_version": 1,
            "models": self.models,
            "weights": self.weights,
            "executables": self.executables,
        }
        with open(os.path.join(self.out, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"  wrote manifest.json ({len(self.executables)} executables, "
              f"{len(self.weights)} weight blobs)")


# ---------------------------------------------------------------------------
# Smoke fixtures (fast-compiling; used by `cargo test`).
# ---------------------------------------------------------------------------


def emit_smoke(w: ArtifactWriter) -> None:
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    def fn_pallas(x, y):
        from .kernels.matmul import matmul_general

        return (matmul_general(x, y, bm=2, bk=2, bn=2) + 2.0,)

    spec = jnp.zeros((2, 2), jnp.float32)
    inp = [
        {"kind": "input", "name": "x", "shape": [2, 2]},
        {"kind": "input", "name": "y", "shape": [2, 2]},
    ]
    w.add_executable(name="smoke", fn=fn, args=inp, arrays=[spec, spec], outputs_of=fn)
    w.add_executable(
        name="smoke_pallas", fn=fn_pallas, args=inp, arrays=[spec, spec], outputs_of=fn_pallas
    )


# ---------------------------------------------------------------------------
# Model executables.
# ---------------------------------------------------------------------------

_BLOCK_FIELDS = [
    "ln1_g", "ln1_b", "wqkv", "bqkv", "wproj", "bproj",
    "ln2_g", "ln2_b", "wfc1", "bfc1", "wfc2", "bfc2",
]
_ATTN_FIELDS = _BLOCK_FIELDS[:6]
_MLP_FIELDS = _BLOCK_FIELDS[6:]


def emit_model(w: ArtifactWriter, cfg: M.ModelConfig, batches: Sequence[int],
               stage_batches: Sequence[int], seed: int) -> None:
    params = M.init_params(cfg, seed=seed)
    t, d = cfg.tokens, cfg.embed_dim
    w.models[cfg.name] = {
        "embed_dim": d,
        "num_heads": cfg.num_heads,
        "depth": cfg.depth,
        "tokens": t,
        "img_size": cfg.img_size,
        "patch_size": cfg.patch_size,
        "num_classes": cfg.num_classes,
        "macs_per_image": M.count_macs(cfg),
    }

    named = _flatten_named(params)
    flat = [leaf for _, leaf in named]
    _, treedef = jax.tree_util.tree_flatten(params)
    full_arg_schema = [
        {"kind": "weight", "weight": w.add_weight(cfg.name, name, leaf)}
        for name, leaf in named
    ]

    # --- full model (sequential-acc executable), one per batch size --------
    for b in batches:
        img = jax.ShapeDtypeStruct((b, cfg.img_size, cfg.img_size, 3), jnp.float32)

        def full_fn(*args):
            ws, x = args[:-1], args[-1]
            p = jax.tree_util.tree_unflatten(treedef, list(ws))
            return (M.model_fwd(p, x, cfg, use_pallas=False),)

        w.add_executable(
            name=f"{cfg.name}_full_b{b}",
            fn=full_fn,
            args=full_arg_schema
            + [{"kind": "input", "name": "img", "shape": list(img.shape)}],
            arrays=flat + [img],
            outputs_of=full_fn,
            extra={"model": cfg.name, "stage": "full", "batch": b},
        )

    # --- stage executables (spatial/hybrid accs) ---------------------------
    embed_named = _flatten_named(params["embed"])
    _, embed_treedef = jax.tree_util.tree_flatten(params["embed"])
    head_named = _flatten_named(params["head"])
    _, head_treedef = jax.tree_util.tree_flatten(params["head"])

    # Per-block weights are runtime arguments: ONE attn/mlp executable is
    # compiled per batch size and re-invoked with each block's weights (the
    # paper's "map several layers onto one physical accelerator").
    block0 = params["blocks"][0]
    blk_weight_ids = {
        f: [w.add_weight(cfg.name, f"blocks/{i}/{f}", params["blocks"][i][f])
            for i in range(cfg.depth)]
        for f in _BLOCK_FIELDS
    }

    for b in stage_batches:
        img = jax.ShapeDtypeStruct((b, cfg.img_size, cfg.img_size, 3), jnp.float32)
        xact = jax.ShapeDtypeStruct((b, t, d), jnp.float32)

        def embed_fn(*args):
            ws, x = args[:-1], args[-1]
            p = jax.tree_util.tree_unflatten(embed_treedef, list(ws))
            return (M.embed_fwd(p, x, cfg, use_pallas=False),)

        w.add_executable(
            name=f"{cfg.name}_embed_b{b}",
            fn=embed_fn,
            args=[{"kind": "weight", "weight": w.add_weight(cfg.name, n, l)}
                  for n, l in embed_named]
            + [{"kind": "input", "name": "img", "shape": list(img.shape)}],
            arrays=[l for _, l in embed_named] + [img],
            outputs_of=embed_fn,
            extra={"model": cfg.name, "stage": "embed", "batch": b},
        )

        def make_sub(fields, fwd):
            def fn(*args):
                ws, x = args[:-1], args[-1]
                bp = dict(zip(fields, ws))
                return (fwd(bp, x, cfg, use_pallas=False),)
            return fn

        for stage, fields, fwd in (
            ("attn", _ATTN_FIELDS, M.attn_fwd),
            ("mlp", _MLP_FIELDS, M.mlp_fwd),
        ):
            w.add_executable(
                name=f"{cfg.name}_{stage}_b{b}",
                fn=make_sub(fields, fwd),
                args=[{"kind": "block_weight", "field": f} for f in fields]
                + [{"kind": "input", "name": "x", "shape": list(xact.shape)}],
                arrays=[block0[f] for f in fields] + [xact],
                outputs_of=make_sub(fields, fwd),
                extra={
                    "model": cfg.name,
                    "stage": stage,
                    "batch": b,
                    "block_weights": {f: blk_weight_ids[f] for f in fields},
                },
            )

        # Class-granular stage executables (one per SSR LayerClass): what
        # lets the rust coordinator serve an 8-class ExecutionPlan directly
        # instead of coarsening it to the four fused stages. Carry-state
        # layouts are documented at model.CLASS_STAGES. The weight-free
        # attention BMMs compile with no block_weight args.
        for stage, fields, fwd, in_width in M.CLASS_STAGES:
            xin = jax.ShapeDtypeStruct((b, t, in_width(cfg)), jnp.float32)
            w.add_executable(
                name=f"{cfg.name}_{stage}_b{b}",
                fn=make_sub(list(fields), fwd),
                args=[{"kind": "block_weight", "field": f} for f in fields]
                + [{"kind": "input", "name": "x", "shape": list(xin.shape)}],
                arrays=[block0[f] for f in fields] + [xin],
                outputs_of=make_sub(list(fields), fwd),
                extra={
                    "model": cfg.name,
                    "stage": stage,
                    "batch": b,
                    "block_weights": {f: blk_weight_ids[f] for f in fields},
                },
            )

        def head_fn(*args):
            ws, x = args[:-1], args[-1]
            p = jax.tree_util.tree_unflatten(head_treedef, list(ws))
            return (M.head_fwd(p, x, cfg, use_pallas=False),)

        w.add_executable(
            name=f"{cfg.name}_head_b{b}",
            fn=head_fn,
            args=[{"kind": "weight", "weight": w.add_weight(cfg.name, n, l)}
                  for n, l in head_named]
            + [{"kind": "input", "name": "x", "shape": list(xact.shape)}],
            arrays=[l for _, l in head_named] + [xact],
            outputs_of=head_fn,
            extra={"model": cfg.name, "stage": "head", "batch": b},
        )

    # --- pallas-kernel block (L1 lowered into the artifact) ----------------
    xact1 = jax.ShapeDtypeStruct((1, t, d), jnp.float32)

    def block_pallas_fn(*args):
        ws, x = args[:-1], args[-1]
        bp = dict(zip(_BLOCK_FIELDS, ws))
        return (M.block_fwd(bp, x, cfg, use_pallas=True),)

    w.add_executable(
        name=f"{cfg.name}_block_pallas_b1",
        fn=block_pallas_fn,
        args=[{"kind": "block_weight", "field": f} for f in _BLOCK_FIELDS]
        + [{"kind": "input", "name": "x", "shape": list(xact1.shape)}],
        arrays=[block0[f] for f in _BLOCK_FIELDS] + [xact1],
        outputs_of=block_pallas_fn,
        extra={
            "model": cfg.name,
            "stage": "block_pallas",
            "batch": 1,
            "block_weights": blk_weight_ids,
        },
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="deit_t",
                    help="comma list from %s or 'all'" % ",".join(M.CONFIGS))
    ap.add_argument("--batches", default="1,3,6", help="full-model batch sizes")
    ap.add_argument("--stage-batches", default="1,6", help="stage batch sizes")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    names = list(M.CONFIGS) if args.models == "all" else args.models.split(",")
    batches = [int(b) for b in args.batches.split(",")]
    stage_batches = [int(b) for b in args.stage_batches.split(",")]

    writer = ArtifactWriter(args.out)
    print("emitting smoke fixtures")
    emit_smoke(writer)
    for name in names:
        cfg = M.CONFIGS[name]
        print(f"emitting {name} (d={cfg.embed_dim} h={cfg.num_heads} "
              f"depth={cfg.depth}, {M.count_macs(cfg)/1e9:.2f} GMACs)")
        emit_model(writer, cfg, batches, stage_batches, args.seed)
    writer.finish()


if __name__ == "__main__":
    main()
