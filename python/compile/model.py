"""L2: DeiT-style vision-transformer forward graph in JAX.

This is the application layer of the paper (Table 3): four ViT variants
(DeiT-T, DeiT-T-160, DeiT-T-256, LV-ViT-T), INT8-quantized in the paper and
fake-quantized here (weights snapped to an int8 grid, f32 compute).

The model is written against the L1 Pallas kernels (``use_pallas=True``) or
the pure-jnp reference ops (``use_pallas=False``); both paths produce the
same numbers (pytest enforces this), and either lowers to a single HLO
module per *stage* for the rust coordinator:

    embed  -> [attn -> mlp] x depth -> head

The stage split is exactly the layer granularity the SSR scheduler assigns to
accelerators (Fig. 4's transformer-block layer graph), so a Layer→Acc
assignment maps 1:1 onto a set of compiled stage executables.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from .kernels import matmul as _km
from .kernels import softmax as _ks
from .kernels import layernorm as _kl
from .kernels import gelu as _kg
from .kernels import ref as _ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Table 3 row: a ViT variant."""

    name: str
    embed_dim: int
    num_heads: int
    depth: int
    mlp_ratio: int = 4
    img_size: int = 224
    patch_size: int = 16
    num_classes: int = 1000

    @property
    def tokens(self) -> int:
        return (self.img_size // self.patch_size) ** 2 + 1  # +1 cls token

    @property
    def head_dim(self) -> int:
        assert self.embed_dim % self.num_heads == 0
        return self.embed_dim // self.num_heads

    @property
    def patch_dim(self) -> int:
        return self.patch_size * self.patch_size * 3


# The four evaluated applications (paper Table 3).
DEIT_T = ModelConfig("deit_t", embed_dim=192, num_heads=3, depth=12)
DEIT_T_160 = ModelConfig("deit_t_160", embed_dim=160, num_heads=4, depth=12)
DEIT_T_256 = ModelConfig("deit_t_256", embed_dim=256, num_heads=4, depth=12)
LV_VIT_T = ModelConfig("lv_vit_t", embed_dim=240, num_heads=4, depth=12)

CONFIGS: Dict[str, ModelConfig] = {
    c.name: c for c in (DEIT_T, DEIT_T_160, DEIT_T_256, LV_VIT_T)
}


def fake_quant_int8(w: jax.Array) -> jax.Array:
    """Symmetric per-tensor fake INT8 quantization (paper runs INT8 models)."""
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / 127.0
    return jnp.round(w / scale) * scale


def init_params(cfg: ModelConfig, seed: int = 0, quantize: bool = True) -> Dict[str, Any]:
    """Seeded synthetic weights (no pretrained checkpoints offline).

    Scaled-normal init; values then snapped to the int8 grid so the artifact
    numerics exercise the same dynamic range as the paper's INT8 deployment.
    """
    key = jax.random.PRNGKey(seed)
    d, h, t = cfg.embed_dim, cfg.mlp_ratio * cfg.embed_dim, cfg.tokens

    def dense(key, fan_in, shape):
        w = jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(fan_in)
        return fake_quant_int8(w) if quantize else w

    keys = iter(jax.random.split(key, 6 + 12 * cfg.depth))
    params: Dict[str, Any] = {
        "embed": {
            "w": dense(next(keys), cfg.patch_dim, (cfg.patch_dim, d)),
            "b": jnp.zeros((d,), jnp.float32),
            "cls": dense(next(keys), d, (1, 1, d)),
            "pos": dense(next(keys), d, (1, t, d)) * 0.02,
        },
        "blocks": [],
        "head": {
            "ln_g": jnp.ones((d,), jnp.float32),
            "ln_b": jnp.zeros((d,), jnp.float32),
            "w": dense(next(keys), d, (d, cfg.num_classes)),
            "b": jnp.zeros((cfg.num_classes,), jnp.float32),
        },
    }
    for _ in range(cfg.depth):
        params["blocks"].append(
            {
                "ln1_g": jnp.ones((d,), jnp.float32),
                "ln1_b": jnp.zeros((d,), jnp.float32),
                "wqkv": dense(next(keys), d, (d, 3 * d)),
                "bqkv": jnp.zeros((3 * d,), jnp.float32),
                "wproj": dense(next(keys), d, (d, d)),
                "bproj": jnp.zeros((d,), jnp.float32),
                "ln2_g": jnp.ones((d,), jnp.float32),
                "ln2_b": jnp.zeros((d,), jnp.float32),
                "wfc1": dense(next(keys), d, (d, h)),
                "bfc1": jnp.zeros((h,), jnp.float32),
                "wfc2": dense(next(keys), h, (h, d)),
                "bfc2": jnp.zeros((d,), jnp.float32),
            }
        )
    return params


# ---------------------------------------------------------------------------
# Op dispatch: pallas kernels vs jnp reference.
# ---------------------------------------------------------------------------


def _mm_pinned(x2d, w, use_pallas):
    return _km.matmul_pinned(x2d, w) if use_pallas else _ref.matmul(x2d, w)


def _bmm(a, b, use_pallas):
    return _km.bmm(a, b) if use_pallas else _ref.bmm(a, b)


def _softmax(x, use_pallas):
    return _ks.softmax_nd(x) if use_pallas else _ref.softmax(x)


def _layernorm(x, g, b, use_pallas):
    if use_pallas:
        return _kl.layernorm_nd(x, g, b)
    return _ref.layernorm(x, g, b)


def _gelu(x, use_pallas):
    return _kg.gelu_nd(x) if use_pallas else _ref.gelu(x)


def _dense(x, w, b, use_pallas):
    """(B, T, Din) @ (Din, Dout) + b — flattened through the 2-D HMM kernel."""
    bs, t, din = x.shape
    y = _mm_pinned(x.reshape(bs * t, din), w, use_pallas)
    return y.reshape(bs, t, -1) + b


# ---------------------------------------------------------------------------
# Stages (the units the SSR scheduler maps onto accelerators).
# ---------------------------------------------------------------------------


def patchify(img: jax.Array, patch: int) -> jax.Array:
    """(B, H, W, 3) -> (B, n_patches, patch*patch*3). Conv-as-MM (Fig. 3's
    patch-embedding kernel is profiled as a matmul-type kernel)."""
    b, hh, ww, c = img.shape
    nh, nw = hh // patch, ww // patch
    x = img.reshape(b, nh, patch, nw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, nh * nw, patch * patch * c)


def embed_fwd(p: Dict[str, Any], img: jax.Array, cfg: ModelConfig, use_pallas=False):
    """Patch embedding + cls token + positional embedding."""
    x = patchify(img, cfg.patch_size)
    x = _dense(x, p["w"], p["b"], use_pallas)
    cls = jnp.broadcast_to(p["cls"], (x.shape[0], 1, cfg.embed_dim))
    x = jnp.concatenate([cls, x], axis=1)
    return x + p["pos"]


def attn_fwd(bp: Dict[str, Any], x: jax.Array, cfg: ModelConfig, use_pallas=False):
    """Pre-LN multi-head attention sublayer with residual."""
    b, t, d = x.shape
    h, dh = cfg.num_heads, cfg.head_dim
    y = _layernorm(x.reshape(b * t, d), bp["ln1_g"], bp["ln1_b"], use_pallas)
    qkv = _mm_pinned(y, bp["wqkv"], use_pallas).reshape(b, t, 3 * d) + bp["bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):  # (B, T, D) -> (B, h, T, dh)
        return z.reshape(b, t, h, dh).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, x.dtype))
    scores = _bmm(q, jnp.swapaxes(k, -1, -2), use_pallas) * scale  # BMM0 (type1)
    probs = _softmax(scores, use_pallas)
    ctx = _bmm(probs, v, use_pallas)  # BMM1 (type1)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, t, d)  # Transpose kernel
    out = _dense(ctx, bp["wproj"], bp["bproj"], use_pallas)
    return x + out


def mlp_fwd(bp: Dict[str, Any], x: jax.Array, cfg: ModelConfig, use_pallas=False):
    """Pre-LN MLP sublayer (fc1 -> GELU -> fc2) with residual."""
    b, t, d = x.shape
    y = _layernorm(x.reshape(b * t, d), bp["ln2_g"], bp["ln2_b"], use_pallas)
    y = _mm_pinned(y, bp["wfc1"], use_pallas) + bp["bfc1"]
    y = _gelu(y, use_pallas)
    y = _mm_pinned(y, bp["wfc2"], use_pallas) + bp["bfc2"]
    return x + y.reshape(b, t, d)


def block_fwd(bp: Dict[str, Any], x: jax.Array, cfg: ModelConfig, use_pallas=False):
    """One full transformer block: attention sublayer then MLP sublayer."""
    return mlp_fwd(bp, attn_fwd(bp, x, cfg, use_pallas), cfg, use_pallas)


# ---------------------------------------------------------------------------
# Class-granular stages (one executable per SSR LayerClass).
#
# The 8-class DSE assigns each MM node class (qkv/bmm0/bmm1/proj/fc1/fc2)
# its own accelerator; serving such an ExecutionPlan needs one executable
# per class. Each function is a single-tensor-in / single-tensor-out step so
# the rust pipeline can forward one activation between workers: state that a
# later class needs (the residual input, V, attention probabilities) rides
# along concatenated on the feature axis. The chain
#
#   qkv -> bmm0 -> bmm1 -> proj -> fc1 -> fc2
#
# computes exactly attn_fwd followed by mlp_fwd (pytest enforces this).
#
# Carry layouts on the feature axis (D = embed_dim, h = heads, T = tokens):
#   qkv  : (B,T,D)            -> (B,T,4D)       [x | qkv]
#   bmm0 : (B,T,4D)           -> (B,T,2D+hT)    [x | v | probs]
#   bmm1 : (B,T,2D+hT)        -> (B,T,2D)       [x | ctx]
#   proj : (B,T,2D)           -> (B,T,D)        x + proj(ctx)
#   fc1  : (B,T,D)            -> (B,T,D+4D)     [x | gelu(fc1(ln2 x))]
#   fc2  : (B,T,D+4D)         -> (B,T,D)        x + fc2(y)
# ---------------------------------------------------------------------------


def qkv_fwd(bp: Dict[str, Any], x: jax.Array, cfg: ModelConfig, use_pallas=False):
    """LN1 + QKV projection; carries the sublayer input for the residual."""
    b, t, d = x.shape
    y = _layernorm(x.reshape(b * t, d), bp["ln1_g"], bp["ln1_b"], use_pallas)
    qkv = _mm_pinned(y, bp["wqkv"], use_pallas).reshape(b, t, 3 * d) + bp["bqkv"]
    return jnp.concatenate([x, qkv], axis=-1)


def bmm0_fwd(bp: Dict[str, Any], s: jax.Array, cfg: ModelConfig, use_pallas=False):
    """Scores = softmax(Q K^T / sqrt(dh)) per head (weight-free, HMM-type1)."""
    b, t, _ = s.shape
    d, h, dh = cfg.embed_dim, cfg.num_heads, cfg.head_dim
    x, qkv = s[..., :d], s[..., d:]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):  # (B, T, D) -> (B, h, T, dh)
        return z.reshape(b, t, h, dh).transpose(0, 2, 1, 3)

    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, s.dtype))
    scores = _bmm(heads(q), jnp.swapaxes(heads(k), -1, -2), use_pallas) * scale
    probs = _softmax(scores, use_pallas)  # (B, h, T, T)
    probs2 = probs.transpose(0, 2, 1, 3).reshape(b, t, h * t)
    return jnp.concatenate([x, v, probs2], axis=-1)


def bmm1_fwd(bp: Dict[str, Any], s: jax.Array, cfg: ModelConfig, use_pallas=False):
    """Context = probs @ V per head, heads merged back (weight-free)."""
    b, t, _ = s.shape
    d, h, dh = cfg.embed_dim, cfg.num_heads, cfg.head_dim
    x, v, probs2 = s[..., :d], s[..., d : 2 * d], s[..., 2 * d :]
    probs = probs2.reshape(b, t, h, t).transpose(0, 2, 1, 3)
    vh = v.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    ctx = _bmm(probs, vh, use_pallas)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, t, d)
    return jnp.concatenate([x, ctx], axis=-1)


def proj_fwd(bp: Dict[str, Any], s: jax.Array, cfg: ModelConfig, use_pallas=False):
    """Output projection + the attention sublayer residual."""
    d = cfg.embed_dim
    x, ctx = s[..., :d], s[..., d:]
    return x + _dense(ctx, bp["wproj"], bp["bproj"], use_pallas)


def fc1_fwd(bp: Dict[str, Any], x: jax.Array, cfg: ModelConfig, use_pallas=False):
    """LN2 + FC1 + GELU; carries the sublayer input for the residual."""
    b, t, d = x.shape
    y = _layernorm(x.reshape(b * t, d), bp["ln2_g"], bp["ln2_b"], use_pallas)
    y = _mm_pinned(y, bp["wfc1"], use_pallas) + bp["bfc1"]
    y = _gelu(y, use_pallas).reshape(b, t, -1)
    return jnp.concatenate([x, y], axis=-1)


def fc2_fwd(bp: Dict[str, Any], s: jax.Array, cfg: ModelConfig, use_pallas=False):
    """FC2 + the MLP sublayer residual."""
    b, t, w = s.shape
    d = cfg.embed_dim
    x, y = s[..., :d], s[..., d:]
    y2 = _mm_pinned(y.reshape(b * t, w - d), bp["wfc2"], use_pallas) + bp["bfc2"]
    return x + y2.reshape(b, t, d)


# Per-class block-weight fields and carry widths (input feature dim as a
# function of cfg), consumed by the AOT path.
CLASS_STAGES = (
    ("qkv", ("ln1_g", "ln1_b", "wqkv", "bqkv"), qkv_fwd,
     lambda cfg: cfg.embed_dim),
    ("bmm0", (), bmm0_fwd,
     lambda cfg: 4 * cfg.embed_dim),
    ("bmm1", (), bmm1_fwd,
     lambda cfg: 2 * cfg.embed_dim + cfg.num_heads * cfg.tokens),
    ("proj", ("wproj", "bproj"), proj_fwd,
     lambda cfg: 2 * cfg.embed_dim),
    ("fc1", ("ln2_g", "ln2_b", "wfc1", "bfc1"), fc1_fwd,
     lambda cfg: cfg.embed_dim),
    ("fc2", ("wfc2", "bfc2"), fc2_fwd,
     lambda cfg: (1 + cfg.mlp_ratio) * cfg.embed_dim),
)


def class_chain_fwd(bp: Dict[str, Any], x: jax.Array, cfg: ModelConfig, use_pallas=False):
    """One block via the six class-granular stages (== block_fwd)."""
    for _, _, fwd, _ in CLASS_STAGES:
        x = fwd(bp, x, cfg, use_pallas)
    return x


def head_fwd(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig, use_pallas=False):
    """Final LayerNorm + classifier on the cls token."""
    b, t, d = x.shape
    y = _layernorm(x.reshape(b * t, d), p["ln_g"], p["ln_b"], use_pallas)
    cls = y.reshape(b, t, d)[:, 0, :]
    return _mm_pinned(cls, p["w"], use_pallas) + p["b"]


def model_fwd(params: Dict[str, Any], img: jax.Array, cfg: ModelConfig, use_pallas=False):
    """End-to-end forward: logits (B, num_classes) from images (B, H, W, 3)."""
    x = embed_fwd(params["embed"], img, cfg, use_pallas)
    for bp in params["blocks"]:
        x = block_fwd(bp, x, cfg, use_pallas)
    return head_fwd(params["head"], x, cfg, use_pallas)


def count_macs(cfg: ModelConfig, batch: int = 1) -> int:
    """Analytical MAC count (matches Table 3's MACs column within ~10%)."""
    t, d, h = cfg.tokens, cfg.embed_dim, cfg.mlp_ratio * cfg.embed_dim
    np_ = t - 1
    macs = np_ * cfg.patch_dim * d  # patch embed
    per_block = (
        t * d * 3 * d  # qkv
        + 2 * cfg.num_heads * t * t * cfg.head_dim  # bmm0 + bmm1
        + t * d * d  # proj
        + t * d * h  # fc1
        + t * h * d  # fc2
    )
    macs += cfg.depth * per_block
    macs += d * cfg.num_classes  # head
    return macs * batch
