"""Pallas HMM (heterogeneous matrix-multiply) kernels.

The paper's HMM unit is an (A, B, C) array of AIE tiles, each computing an
(h1, w1, w2) sub-matmul out of its 32 KiB local memory, fed by PLIO streams
from PL-side RAM banks. On TPU the analogous schedule is expressed with a
Pallas grid + ``BlockSpec``s:

* the grid dimension order plays the role of the PLIO stream schedule
  (which operand is revisited / resident across iterations),
* the block shape ``(TM, TK, TN)`` plays the role of the per-array-pass tile
  ``(A*h1, B*w1, C*w2)``,
* VMEM residency of the weight block across the M-grid plays the role of
  HMM-type0 *weight pinning* into AIE local memory.

Both kernels accumulate in f32 (``preferred_element_type``), the analog of
the AIE's 32-bit accumulators over INT8 MACs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    """Zero-pad ``x`` along ``axis`` up to a multiple of ``mult``.

    The paper's DSE only admits integer tilings (Sec 4.4: "we find all integer
    solutions"); padding is how a fixed (TM,TK,TN) tile covers ragged shapes
    like the 197-token dimension, exactly as the AIE array pads its last pass.
    """
    size = x.shape[axis]
    rem = size % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(x, pad)


def _mm_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """Blocked matmul body: accumulate over the K grid dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _blocked_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int,
    bk: int,
    bn: int,
    pin_weights: bool,
) -> jax.Array:
    """Shared driver for both HMM types.

    ``pin_weights`` selects the grid order: type0 iterates the M dimension
    innermost so the weight block (k, j) stays VMEM-resident across the whole
    activation stream — the schedule the paper gets by pinning weights in AIE
    local memory and streaming only activations over PLIO.
    """
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, f"matmul shape mismatch: {x.shape} @ {w.shape}"

    bm = min(bm, m) if m > 0 else bm
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    nm, nn, nk = mp // bm, np_ // bn, kp // bk

    if pin_weights:
        # grid = (j, k, i): for a fixed weight block (k, j) the whole M range
        # streams through before the next weight block is loaded.
        grid = (nn, nk, nm)
        x_spec = pl.BlockSpec((bm, bk), lambda j, k, i: (i, k))
        w_spec = pl.BlockSpec((bk, bn), lambda j, k, i: (k, j))
        o_spec = pl.BlockSpec((bm, bn), lambda j, k, i: (i, j))

        def kernel(x_ref, w_ref, o_ref):
            k = pl.program_id(1)

            @pl.when(k == 0)
            def _init():
                o_ref[...] = jnp.zeros_like(o_ref)

            o_ref[...] += jnp.dot(
                x_ref[...], w_ref[...], preferred_element_type=jnp.float32
            )

        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[x_spec, w_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, wp)
    else:
        grid = (nm, nn, nk)
        out = pl.pallas_call(
            functools.partial(_mm_kernel, nk=nk),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, wp)

    return out[:m, :n]


def matmul_pinned(
    x: jax.Array, w: jax.Array, *, bm: int = 64, bk: int = 64, bn: int = 64
) -> jax.Array:
    """HMM-type0: weight-stationary matmul (QKV / proj / MLP layers).

    One streamed operand (activations); weights are grid-resident. Matches
    the paper's PLIO-reduction strategy for non-attention layers.
    """
    return _blocked_matmul(x, w, bm=bm, bk=bk, bn=bn, pin_weights=True)


def matmul_general(
    x: jax.Array, y: jax.Array, *, bm: int = 64, bk: int = 64, bn: int = 64
) -> jax.Array:
    """HMM-type1: general matmul with two streamed activation operands.

    Used for attention score (Q @ K^T) and context (P @ V) products where
    both operands are activations and cannot be pinned.
    """
    return _blocked_matmul(x, y, bm=bm, bk=bk, bn=bn, pin_weights=False)


def bmm(
    x: jax.Array, y: jax.Array, *, bm: int = 64, bk: int = 64, bn: int = 64
) -> jax.Array:
    """Batched HMM-type1 over arbitrary leading dims (heads, batch)."""
    assert x.ndim == y.ndim and x.ndim >= 2
    if x.ndim == 2:
        return matmul_general(x, y, bm=bm, bk=bk, bn=bn)
    fn = functools.partial(bmm, bm=bm, bk=bk, bn=bn)
    return jax.vmap(fn)(x, y)
