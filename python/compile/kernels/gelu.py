"""Pallas HCE GELU kernel (elementwise, reuse distance == 1).

Elementwise ops fuse trivially with the HMM stream in the paper (Sec 4.3);
here the kernel is a plain blocked elementwise map, the degenerate case of
the fine-grained pipeline.

Uses the tanh approximation (as deployed INT8 transformer accelerators do):
    gelu(x) = 0.5 x (1 + tanh(sqrt(2/pi) (x + 0.044715 x^3)))
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SQRT_2_OVER_PI = 0.7978845608028654


def _gelu_kernel(x_ref, o_ref):
    x = x_ref[...]
    inner = _SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)
    o_ref[...] = 0.5 * x * (1.0 + jnp.tanh(inner))


def gelu(x: jax.Array, *, block_rows: int = 128) -> jax.Array:
    """Blocked elementwise tanh-GELU on a 2-D array."""
    assert x.ndim == 2
    rows, cols = x.shape
    br = min(block_rows, rows)
    pad_r = (-rows) % br
    xp = jnp.pad(x, ((0, pad_r), (0, 0)))
    nrb = xp.shape[0] // br

    out = pl.pallas_call(
        _gelu_kernel,
        grid=(nrb,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp)
    return out[:rows, :]


def gelu_nd(x: jax.Array) -> jax.Array:
    """GELU for arbitrary leading dims."""
    shape = x.shape
    return gelu(x.reshape(-1, shape[-1])).reshape(shape)
