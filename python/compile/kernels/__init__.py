"""L1 Pallas kernels for the SSR reproduction.

Each kernel module mirrors a hardware unit from the paper:

* ``matmul``      — HMM (heterogeneous matrix-multiply) units on the AIE array.
  ``matmul.matmul_pinned`` is HMM-type0 (weights pinned in AIE local memory /
  VMEM), ``matmul.matmul_general`` is HMM-type1 (two streamed activation
  operands, used by attention score/context products).
* ``softmax``     — HCE nonlinear engine (PL side in the paper).
* ``layernorm``   — HCE nonlinear engine with the line-buffer fine-grained
  pipeline realized as a single fused mu/sigma pass.
* ``gelu``        — HCE elementwise engine.
* ``ref``         — pure-jnp oracles for all of the above.

Import the *modules* (``from compile.kernels import softmax``) — the
function names inside intentionally match the module names, so re-exporting
them here would shadow the submodules.

All kernels run under ``interpret=True`` (CPU); real-TPU performance is
estimated analytically (see DESIGN.md §Hardware-Adaptation and §Perf).
"""

from . import gelu, layernorm, matmul, ref, softmax

__all__ = ["matmul", "softmax", "layernorm", "gelu", "ref"]
