"""Pure-jnp oracles for every L1 kernel — the correctness ground truth.

These are deliberately *naive* (e.g. the two-pass LayerNorm the paper's
Fig. 7 shows as the unpipelined baseline) so the fused Pallas kernels are
checked against an independent formulation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_SQRT_2_OVER_PI = 0.7978845608028654


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def bmm(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def softmax(x: jax.Array) -> jax.Array:
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layernorm(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, *, eps: float = 1e-6
) -> jax.Array:
    # Two-pass formulation: mu first, then centered variance (the paper's
    # unpipelined dependency chain) — numerically independent of the kernel's
    # fused E[x^2]-E[x]^2 form.
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def gelu(x: jax.Array) -> jax.Array:
    inner = _SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)
    return 0.5 * x * (1.0 + jnp.tanh(inner))


def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Single-head scaled dot-product attention oracle. q,k,v: (T, dh)."""
    dh = q.shape[-1]
    scores = bmm(q, jnp.swapaxes(k, -1, -2)) / jnp.sqrt(jnp.asarray(dh, q.dtype))
    return bmm(softmax(scores), v)
