"""Pallas HCE LayerNorm kernel — the fine-grained-pipeline analog.

Paper Fig. 7: the PL LayerNorm engine must produce mu, then sigma, then the
normalized output, and without pipelining these stages serialize and can
dominate the MM latency. SSR's fix is a bypass line buffer that starts the
sigma stage as soon as the first row's mu is ready, roughly halving latency.

The VMEM analog: a row block is resident, so mu and sigma are computed in a
*single fused traversal* using the one-pass identity

    var = E[x^2] - (E[x])^2

— i.e. the sum and sum-of-squares accumulate together, which is exactly the
dependency the line buffer breaks. ``ref.py`` holds the naive two-pass
oracle; the property tests check the fused kernel against it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, valid_cols: int, eps: float):
    x = x_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, dimension=x.ndim - 1)
    mask = col < valid_cols
    xz = jnp.where(mask, x, 0.0)
    n = jnp.asarray(valid_cols, x.dtype)
    # Single fused pass: sum and sum-of-squares together (line-buffer analog).
    s1 = jnp.sum(xz, axis=-1, keepdims=True)
    s2 = jnp.sum(xz * xz, axis=-1, keepdims=True)
    mu = s1 / n
    var = s2 / n - mu * mu
    inv = jax.lax.rsqrt(var + eps)
    y = (xz - mu) * inv * g_ref[...] + b_ref[...]
    o_ref[...] = jnp.where(mask, y, 0.0)


def layernorm(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 128,
) -> jax.Array:
    """LayerNorm over the last axis of a 2-D array with affine params."""
    assert x.ndim == 2
    rows, cols = x.shape
    assert gamma.shape == (cols,) and beta.shape == (cols,)
    br = min(block_rows, rows)
    pad_r = (-rows) % br
    xp = jnp.pad(x, ((0, pad_r), (0, 0)))
    nrb = xp.shape[0] // br

    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, valid_cols=cols, eps=eps),
        grid=(nrb,),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((cols,), lambda i: (0,)),
            pl.BlockSpec((cols,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp, gamma, beta)
    return out[:rows, :]


def layernorm_nd(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, *, eps: float = 1e-6
) -> jax.Array:
    """LayerNorm over the last axis for arbitrary leading dims."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    return layernorm(flat, gamma, beta, eps=eps).reshape(shape)
