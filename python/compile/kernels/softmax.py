"""Pallas HCE softmax kernel.

In the paper, Softmax is a PL-side nonlinear engine whose reduction (row max
and row sum) has reuse distance > 1, so it is pipelined with a bypass line
buffer (Fig. 7). In the Pallas mapping a row block lives entirely in VMEM, so
the max/exp/sum stages fuse into one traversal of the resident block — the
same dependency-resolution trick, expressed as block residency instead of a
line buffer.

The kernel blocks over rows and keeps the full (padded) reduction axis in the
block, which for transformer shapes (<=1024 columns) fits VMEM comfortably.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref, *, valid_cols: int):
    x = x_ref[...]
    # Mask padded columns so they contribute exp(-inf) = 0 to the sum.
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, dimension=x.ndim - 1)
    neg_inf = jnp.asarray(-jnp.inf, x.dtype)
    x = jnp.where(col < valid_cols, x, neg_inf)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = e / s


def softmax(x: jax.Array, *, block_rows: int = 128) -> jax.Array:
    """Row softmax over the last axis of a 2-D array (rows are independent)."""
    assert x.ndim == 2, "softmax kernel operates on (rows, cols)"
    rows, cols = x.shape
    br = min(block_rows, rows)
    pad_r = (-rows) % br
    xp = jnp.pad(x, ((0, pad_r), (0, 0)))
    nrb = xp.shape[0] // br

    import functools

    out = pl.pallas_call(
        functools.partial(_softmax_kernel, valid_cols=cols),
        grid=(nrb,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp)
    return out[:rows, :]


def softmax_nd(x: jax.Array, *, block_rows: int = 128) -> jax.Array:
    """Softmax over the last axis for arbitrary leading dims (heads, batch)."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    return softmax(flat, block_rows=block_rows).reshape(shape)
