"""L1 kernels vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes (including the ragged 197-token dimension and
non-divisible head dims) and block configurations, asserting allclose
against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import compile.kernels.matmul as km
import compile.kernels.softmax as ks
import compile.kernels.layernorm as kl
import compile.kernels.gelu as kg
from compile.kernels import ref


def rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


TOL = dict(rtol=2e-5, atol=2e-5)

dims = st.integers(min_value=1, max_value=80)
blocks = st.sampled_from([4, 16, 32, 64, 128])


class TestMatmul:
    @settings(max_examples=10, deadline=None)
    @given(m=dims, k=dims, n=dims, bm=blocks, bk=blocks, bn=blocks)
    def test_general_matches_ref(self, m, k, n, bm, bk, bn):
        x, w = rand(1, m, k), rand(2, k, n)
        got = km.matmul_general(x, w, bm=bm, bk=bk, bn=bn)
        np.testing.assert_allclose(got, ref.matmul(x, w), **TOL)

    @settings(max_examples=10, deadline=None)
    @given(m=dims, k=dims, n=dims, bm=blocks, bk=blocks, bn=blocks)
    def test_pinned_matches_ref(self, m, k, n, bm, bk, bn):
        x, w = rand(3, m, k), rand(4, k, n)
        got = km.matmul_pinned(x, w, bm=bm, bk=bk, bn=bn)
        np.testing.assert_allclose(got, ref.matmul(x, w), **TOL)

    def test_pinned_equals_general(self):
        # HMM-type0 and type1 differ only in schedule, never in numerics.
        x, w = rand(5, 197, 192), rand(6, 192, 576)
        a = km.matmul_pinned(x, w)
        b = km.matmul_general(x, w)
        np.testing.assert_allclose(a, b, **TOL)

    def test_deit_shapes(self):
        # The exact QKV shape from DeiT-T: ragged M=197 exercises padding.
        x, w = rand(7, 197, 192), rand(8, 192, 576)
        np.testing.assert_allclose(
            km.matmul_pinned(x, w), ref.matmul(x, w), **TOL
        )

    def test_bmm_heads(self):
        q = rand(9, 2, 3, 197, 64)
        k = rand(10, 2, 3, 64, 197)
        np.testing.assert_allclose(km.bmm(q, k), ref.bmm(q, k), **TOL)

    def test_bmm_2d_passthrough(self):
        x, y = rand(11, 8, 8), rand(12, 8, 8)
        np.testing.assert_allclose(km.bmm(x, y), ref.bmm(x, y), **TOL)

    def test_under_jit(self):
        x, w = rand(13, 33, 17), rand(14, 17, 29)
        got = jax.jit(lambda a, b: km.matmul_general(a, b))(x, w)
        np.testing.assert_allclose(got, ref.matmul(x, w), **TOL)

    @pytest.mark.parametrize("m,k,n", [(1, 1, 1), (1, 64, 1), (64, 1, 64)])
    def test_degenerate_dims(self, m, k, n):
        x, w = rand(15, m, k), rand(16, k, n)
        np.testing.assert_allclose(
            km.matmul_general(x, w), ref.matmul(x, w), **TOL
        )


class TestSoftmax:
    @settings(max_examples=8, deadline=None)
    @given(r=dims, c=st.integers(min_value=1, max_value=256),
           br=st.sampled_from([1, 8, 64, 128]))
    def test_matches_ref(self, r, c, br):
        x = rand(21, r, c, scale=3.0)
        got = ks.softmax(x, block_rows=br)
        np.testing.assert_allclose(got, ref.softmax(x), **TOL)

    def test_rows_sum_to_one(self):
        x = rand(22, 197, 197, scale=10.0)
        got = ks.softmax(x)
        np.testing.assert_allclose(np.sum(got, -1), np.ones(197), **TOL)

    def test_extreme_values_stable(self):
        x = jnp.array([[1e4, -1e4, 0.0], [-1e4, -1e4, -1e4]], jnp.float32)
        got = ks.softmax(x)
        assert np.all(np.isfinite(got))
        np.testing.assert_allclose(got, ref.softmax(x), **TOL)

    def test_nd_wrapper(self):
        x = rand(23, 2, 3, 197, 197)
        np.testing.assert_allclose(ks.softmax_nd(x), ref.softmax(x), **TOL)


class TestLayerNorm:
    @settings(max_examples=8, deadline=None)
    @given(r=dims, c=st.integers(min_value=2, max_value=256),
           br=st.sampled_from([1, 8, 64, 128]))
    def test_matches_two_pass_ref(self, r, c, br):
        x = rand(31, r, c, scale=2.0)
        g = 1.0 + 0.1 * rand(32, c)
        b = 0.1 * rand(33, c)
        got = kl.layernorm(x, g, b, block_rows=br)
        np.testing.assert_allclose(got, ref.layernorm(x, g, b), rtol=1e-4, atol=1e-4)

    def test_output_statistics(self):
        # unit affine => rows should be ~zero-mean, ~unit-variance
        x = rand(34, 64, 192, scale=5.0)
        got = kl.layernorm(x, jnp.ones(192), jnp.zeros(192))
        np.testing.assert_allclose(np.mean(got, -1), np.zeros(64), atol=1e-4)
        np.testing.assert_allclose(np.var(got, -1), np.ones(64), atol=1e-2)

    def test_shift_invariance(self):
        # LayerNorm(x + c) == LayerNorm(x): the fused one-pass form must not
        # lose this (it is where E[x^2]-E[x]^2 catastrophically cancels).
        x = rand(35, 16, 64)
        g, b = jnp.ones(64), jnp.zeros(64)
        np.testing.assert_allclose(
            kl.layernorm(x + 100.0, g, b), kl.layernorm(x, g, b),
            rtol=2e-2, atol=2e-2,
        )

    def test_nd_wrapper(self):
        x = rand(36, 2, 197, 192)
        g, b = jnp.ones(192), jnp.zeros(192)
        np.testing.assert_allclose(
            kl.layernorm_nd(x, g, b), ref.layernorm(x, g, b), rtol=1e-4, atol=1e-4
        )


class TestGelu:
    @settings(max_examples=8, deadline=None)
    @given(r=dims, c=dims, br=st.sampled_from([1, 16, 128]))
    def test_matches_ref(self, r, c, br):
        x = rand(41, r, c, scale=3.0)
        np.testing.assert_allclose(kg.gelu(x, block_rows=br), ref.gelu(x), **TOL)

    def test_known_values(self):
        x = jnp.array([[0.0, 1.0, -1.0, 10.0, -10.0]], jnp.float32)
        got = np.asarray(kg.gelu(x))[0]
        assert got[0] == 0.0
        assert abs(got[1] - 0.8412) < 1e-3  # gelu(1)
        assert abs(got[3] - 10.0) < 1e-4    # saturates to identity
        assert abs(got[4]) < 1e-4           # saturates to zero

    def test_nd_wrapper(self):
        x = rand(42, 2, 7, 33)
        np.testing.assert_allclose(kg.gelu_nd(x), ref.gelu(x), **TOL)


class TestAttentionComposition:
    def test_kernel_attention_matches_oracle(self):
        # Compose score/softmax/context from L1 kernels and check against the
        # single-call oracle — the HMM-type1 + HCE pipeline end to end.
        t, dh = 50, 32
        q, k, v = rand(51, t, dh), rand(52, t, dh), rand(53, t, dh)
        scale = 1.0 / np.sqrt(dh)
        scores = km.matmul_general(q, jnp.swapaxes(k, -1, -2)) * scale
        got = km.matmul_general(ks.softmax(scores), v)
        np.testing.assert_allclose(got, ref.attention(q, k, v), rtol=1e-4, atol=1e-4)
