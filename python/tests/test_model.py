"""L2 model tests: shapes, stage composition, pallas/jnp equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


# Small config so pallas-path tests stay fast; same structure as DeiT-T.
TINY = M.ModelConfig("tiny", embed_dim=32, num_heads=2, depth=2,
                     img_size=32, patch_size=16, num_classes=10)


@pytest.fixture(scope="module")
def tiny_params():
    return M.init_params(TINY, seed=7)


@pytest.fixture(scope="module")
def tiny_img():
    return jax.random.normal(jax.random.PRNGKey(11), (2, 32, 32, 3), jnp.float32)


class TestConfigs:
    def test_table3_configs_present(self):
        assert set(M.CONFIGS) == {"deit_t", "deit_t_160", "deit_t_256", "lv_vit_t"}

    def test_deit_t_dims(self):
        c = M.DEIT_T
        assert (c.embed_dim, c.num_heads, c.depth) == (192, 3, 12)
        assert c.tokens == 197 and c.head_dim == 64

    @pytest.mark.parametrize(
        "name,paper_gmacs",
        # Table 3 MACs column (G). Our analytical count should land within
        # ~15% (the paper rounds and may count conv differently).
        [("deit_t", 1.3), ("deit_t_160", 0.9), ("deit_t_256", 2.1), ("lv_vit_t", 1.6)],
    )
    def test_macs_match_table3(self, name, paper_gmacs):
        got = M.count_macs(M.CONFIGS[name]) / 1e9
        assert abs(got - paper_gmacs) / paper_gmacs < 0.20, (name, got)

    def test_param_count_deit_t(self):
        # Table 3: DeiT-T = 5.6M params.
        p = M.init_params(M.DEIT_T, seed=0)
        n = sum(np.prod(np.shape(l)) for l in jax.tree_util.tree_leaves(p))
        assert 5.0e6 < n < 6.5e6

    def test_batch_macs_scale(self):
        assert M.count_macs(M.DEIT_T, batch=6) == 6 * M.count_macs(M.DEIT_T)


class TestForward:
    def test_patchify_shape(self, tiny_img):
        x = M.patchify(tiny_img, 16)
        assert x.shape == (2, 4, 16 * 16 * 3)

    def test_patchify_preserves_values(self):
        img = jnp.arange(1 * 32 * 32 * 3, dtype=jnp.float32).reshape(1, 32, 32, 3)
        x = M.patchify(img, 16)
        # first patch, first row of pixels == image top-left 16 pixels
        np.testing.assert_array_equal(
            np.asarray(x)[0, 0, : 16 * 3], np.asarray(img)[0, 0, :16, :].ravel()
        )

    def test_full_forward_shape(self, tiny_params, tiny_img):
        out = M.model_fwd(tiny_params, tiny_img, TINY)
        assert out.shape == (2, 10)
        assert np.all(np.isfinite(out))

    def test_stage_composition_equals_full(self, tiny_params, tiny_img):
        # embed -> blocks -> head composed stage-by-stage must equal the
        # monolithic forward: this is what lets the coordinator split the
        # model across accelerators without changing numerics.
        x = M.embed_fwd(tiny_params["embed"], tiny_img, TINY)
        for bp in tiny_params["blocks"]:
            x = M.attn_fwd(bp, x, TINY)
            x = M.mlp_fwd(bp, x, TINY)
        staged = M.head_fwd(tiny_params["head"], x, TINY)
        full = M.model_fwd(tiny_params, tiny_img, TINY)
        np.testing.assert_allclose(staged, full, rtol=1e-5, atol=1e-5)

    def test_class_chain_equals_block(self, tiny_params, tiny_img):
        # The six class-granular stages (qkv -> bmm0 -> bmm1 -> proj -> fc1
        # -> fc2, carry-state convention) must reproduce the fused block
        # exactly: this is what lets the rust coordinator serve an 8-class
        # ExecutionPlan without changing numerics.
        x = M.embed_fwd(tiny_params["embed"], tiny_img, TINY)
        bp = tiny_params["blocks"][0]
        fused = M.block_fwd(bp, x, TINY)
        chained = M.class_chain_fwd(bp, x, TINY)
        np.testing.assert_allclose(chained, fused, rtol=1e-5, atol=1e-5)

    def test_class_stage_carry_widths(self, tiny_params, tiny_img):
        # Each class stage's input width matches the CLASS_STAGES contract
        # the AOT path compiles against.
        x = M.embed_fwd(tiny_params["embed"], tiny_img, TINY)
        bp = tiny_params["blocks"][0]
        for name, _, fwd, in_width in M.CLASS_STAGES:
            assert x.shape[-1] == in_width(TINY), name
            x = fwd(bp, x, TINY)
        assert x.shape[-1] == TINY.embed_dim  # fc2 closes the block

    def test_block_fwd_is_attn_then_mlp(self, tiny_params, tiny_img):
        x = M.embed_fwd(tiny_params["embed"], tiny_img, TINY)
        bp = tiny_params["blocks"][0]
        a = M.block_fwd(bp, x, TINY)
        b = M.mlp_fwd(bp, M.attn_fwd(bp, x, TINY), TINY)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    def test_pallas_path_matches_jnp_path(self, tiny_params, tiny_img):
        # The L1-kernel model and the reference model agree end to end.
        a = M.model_fwd(tiny_params, tiny_img, TINY, use_pallas=False)
        b = M.model_fwd(tiny_params, tiny_img, TINY, use_pallas=True)
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)

    def test_pallas_block_matches_jnp_block(self, tiny_params, tiny_img):
        x = M.embed_fwd(tiny_params["embed"], tiny_img, TINY)
        bp = tiny_params["blocks"][1]
        a = M.block_fwd(bp, x, TINY, use_pallas=False)
        b = M.block_fwd(bp, x, TINY, use_pallas=True)
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)

    def test_batch_independence(self, tiny_params, tiny_img):
        # Row i of a batched forward == forward of row i alone (no cross-batch
        # leakage through any kernel's padding/blocking).
        full = M.model_fwd(tiny_params, tiny_img, TINY)
        one = M.model_fwd(tiny_params, tiny_img[:1], TINY)
        np.testing.assert_allclose(full[:1], one, rtol=1e-4, atol=1e-4)

    def test_deterministic_init(self):
        a = M.init_params(TINY, seed=3)
        b = M.init_params(TINY, seed=3)
        for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(la, lb)

    def test_fake_quant_levels(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
        q = M.fake_quant_int8(w)
        lv = np.unique(np.round(np.asarray(q) / (np.abs(np.asarray(q)).max() / 127.0)))
        assert len(lv) <= 255  # at most 255 int8 levels
