"""AOT exporter tests: manifest schema, weight blobs, HLO text contract."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

TINY = M.ModelConfig("tiny", embed_dim=32, num_heads=2, depth=2,
                     img_size=32, patch_size=16, num_classes=10)


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("arts")
    w = aot.ArtifactWriter(str(out))
    aot.emit_smoke(w)
    aot.emit_model(w, TINY, batches=[1], stage_batches=[1], seed=3)
    w.finish()
    return out


def load_manifest(out):
    with open(os.path.join(out, "manifest.json")) as f:
        return json.load(f)


class TestManifest:
    def test_schema_fields(self, emitted):
        m = load_manifest(emitted)
        assert m["format_version"] == 1
        assert "tiny" in m["models"]
        names = {e["name"] for e in m["executables"]}
        assert {"smoke", "smoke_pallas", "tiny_full_b1", "tiny_embed_b1",
                "tiny_attn_b1", "tiny_mlp_b1", "tiny_head_b1",
                "tiny_block_pallas_b1"} <= names

    def test_weight_ids_dense_and_files_exist(self, emitted):
        m = load_manifest(emitted)
        for i, w in enumerate(m["weights"]):
            assert w["id"] == i
            path = os.path.join(emitted, w["file"])
            assert os.path.exists(path)
            elems = int(np.prod(w["shape"])) if w["shape"] else 1
            assert os.path.getsize(path) == elems * 4

    def test_block_weights_cover_depth(self, emitted):
        m = load_manifest(emitted)
        attn = next(e for e in m["executables"] if e["name"] == "tiny_attn_b1")
        for field, ids in attn["block_weights"].items():
            assert len(ids) == TINY.depth, field

    def test_class_stage_executables_present_with_carry_widths(self, emitted):
        # One executable per SSR LayerClass, input width per the
        # CLASS_STAGES carry contract; the weight-free attention BMMs carry
        # no block weights (the rust runtime runs them without a block idx).
        m = load_manifest(emitted)
        by_name = {e["name"]: e for e in m["executables"]}
        for stage, fields, _, in_width in M.CLASS_STAGES:
            e = by_name[f"tiny_{stage}_b1"]
            assert e["stage"] == stage and e["batch"] == 1
            (inp,) = [a for a in e["args"] if a["kind"] == "input"]
            assert inp["shape"] == [1, TINY.tokens, in_width(TINY)], stage
            assert set(e.get("block_weights", {})) == set(fields), stage
            if not fields:
                assert e["args"] == [inp], f"{stage} must be weight-free"

    def test_input_args_have_shapes(self, emitted):
        m = load_manifest(emitted)
        full = next(e for e in m["executables"] if e["name"] == "tiny_full_b1")
        inputs = [a for a in full["args"] if a["kind"] == "input"]
        assert inputs == [{"kind": "input", "name": "img", "shape": [1, 32, 32, 3]}]
        assert full["outputs"] == [[1, 10]]


class TestHloText:
    def test_hlo_files_are_parseable_text(self, emitted):
        m = load_manifest(emitted)
        for e in m["executables"]:
            text = open(os.path.join(emitted, e["hlo"])).read()
            assert text.startswith("HloModule"), e["name"]
            assert "ENTRY" in text

    def test_to_hlo_text_matches_eval(self):
        # The exported computation and direct jax eval agree (round-trip via
        # the XLA client that aot uses for conversion).
        def fn(x):
            return (x * 2.0 + 1.0,)

        spec = jax.ShapeDtypeStruct((3,), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fn).lower(spec))
        assert "HloModule" in text

    def test_weights_roundtrip_bitexact(self, emitted, tmp_path):
        # Read a blob back and compare with freshly initialized params.
        params = M.init_params(TINY, seed=3)
        m = load_manifest(emitted)
        spec = next(w for w in m["weights"] if w["name"].endswith("blocks/0/wqkv"))
        data = np.fromfile(os.path.join(emitted, spec["file"]), dtype="<f4")
        want = np.asarray(params["blocks"][0]["wqkv"], dtype=np.float32).ravel()
        np.testing.assert_array_equal(data, want)


class TestDedup:
    def test_shared_weights_not_duplicated(self, emitted):
        # Stage executables reference the same blocks/0/wqkv blob as the
        # full model (dedup by array identity).
        m = load_manifest(emitted)
        names = [w["name"] for w in m["weights"]]
        assert len(names) == len(set(names)), "duplicate weight blobs"
