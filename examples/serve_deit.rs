//! END-TO-END DRIVER: serve DeiT-T on the real PJRT runtime and measure
//! the latency-throughput tradeoff of the three execution models with
//! actual compiled executables — the full three-layer system composing:
//!
//!   Pallas/JAX (build time) -> HLO artifacts -> rust PJRT coordinator.
//!
//! * sequential: one worker, monolithic `full_bN` executable per request
//!   (Fig. 1a — latency-oriented at batch 1, throughput via batching),
//! * spatial: four stage workers (embed/attn/mlp/head) with requests
//!   pipelined across them (Fig. 1b),
//! * hybrid: two workers ({embed,mlp,head}, {attn}) (Fig. 1c),
//! * plan-driven 8-class hybrid: an `ExecutionPlan` for a DSE-style
//!   assignment with attention split across accelerators (nacc = 5) —
//!   unservable under the old 4-stage projection, served directly here.
//!
//! Requires `make artifacts`. Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run with: `cargo run --release --example serve_deit [-- --requests N]`

use std::sync::Arc;

use ssr::coordinator::pipeline::{synth_images, PipelineServer, SequentialServer};
use ssr::coordinator::StageAssign;
use ssr::dse::Assignment;
use ssr::plan::ExecutionPlan;
use ssr::runtime::exec::Engine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args
        .iter()
        .position(|a| a == "--requests")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(12);

    let dir = ssr::runtime::artifacts_dir(None);
    let engine = Engine::load(&dir)?;
    println!(
        "PJRT engine: {} | {} executables, {} weight blobs ({:.1} MB)\n",
        engine.platform(),
        engine.manifest.executables.len(),
        engine.weights.len(),
        engine.weights.bytes() as f64 / 1e6
    );

    // --- sequential: batch sweep on the monolithic executable -------------
    println!("== sequential (monolithic acc, Fig. 1a) ==");
    let seq = SequentialServer::new(Arc::clone(&engine), "deit_t", &[1, 3, 6])?;
    let mut seq_points = Vec::new();
    for &b in &[1usize, 3, 6] {
        let nreq = (requests / b).max(2);
        let reqs: Vec<_> =
            (0..nreq).map(|i| synth_images(b, seq.img_size(), i as u64)).collect();
        let (report, outs) = seq.serve(b, &reqs)?;
        assert!(outs.iter().all(|o| o.data.iter().all(|x| x.is_finite())));
        println!(
            "  batch {b}: lat/req p50 {:>8.2} ms | {:>6.2} img/s | {:.4} eff TOPS",
            report.latency.p50() * 1e3,
            report.throughput_rps(),
            report.effective_tops()
        );
        seq_points.push((b, report));
    }

    // --- spatial + hybrid pipelines ---------------------------------------
    for (name, assign) in [
        ("spatial (4 stage accs, Fig. 1b)", StageAssign::spatial()),
        ("hybrid  (2 accs: {embed,mlp,head} | {attn}, Fig. 1c)",
         StageAssign { acc_of: [0, 1, 0, 0] }),
    ] {
        println!("\n== {name} ==");
        let pipe = PipelineServer::new(Arc::clone(&engine), "deit_t", &assign, 1)?;
        let imgs: Vec<_> = (0..requests).map(|i| synth_images(1, 224, i as u64)).collect();
        let (report, outs) = pipe.serve(imgs)?;
        assert!(outs.iter().all(|o| o.shape == vec![1, 1000]));
        println!(
            "  {} requests: lat p50 {:>8.2} ms p99 {:>8.2} ms | {:>6.2} img/s | {:.4} eff TOPS",
            report.requests,
            report.latency.p50() * 1e3,
            report.latency.p99() * 1e3,
            report.throughput_rps(),
            report.effective_tops()
        );
    }

    // --- plan-driven 8-class hybrid (DSE -> ExecutionPlan -> serve) --------
    // Attention split across two accs, MLP across two more: nacc = 5. The
    // old 4-stage projection collapses this to <= 3 accs; the plan serves
    // it as designed (or logs the coarsening if the manifest predates the
    // class-granular stage executables).
    let assignment = Assignment::new(vec![0, 1, 2, 2, 1, 3, 4, 0]);
    let (_, report) = StageAssign::try_from_assignment(&assignment);
    println!("\n== plan-driven hybrid (8-class, {} accs) ==", assignment.nacc());
    println!("  old 4-stage projection would be {}", report.describe());
    let depth = engine.manifest.models["deit_t"].depth;
    let plan = ExecutionPlan::from_depth("deit_t", depth, &assignment, 1);
    let pipe = PipelineServer::from_plan(Arc::clone(&engine), &plan)?;
    println!("  serving: {}", pipe.plan().summary());
    let imgs: Vec<_> = (0..requests).map(|i| synth_images(1, 224, i as u64)).collect();
    let (report, outs) = pipe.serve(imgs)?;
    assert!(outs.iter().all(|o| o.shape == vec![1, 1000]));
    println!(
        "  {} requests: lat p50 {:>8.2} ms p99 {:>8.2} ms | {:>6.2} img/s | {:.4} eff TOPS",
        report.requests,
        report.latency.p50() * 1e3,
        report.latency.p99() * 1e3,
        report.throughput_rps(),
        report.effective_tops()
    );
    // correctness: plan-served logits equal the monolithic executable
    let img = synth_images(1, 224, 777);
    let want = seq.run_batch(1, &img)?;
    let (_, got) = pipe.serve(vec![img])?;
    let diff = want
        .data
        .iter()
        .zip(&got[0].data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!("  max |logit diff| vs monolithic = {diff:.2e} (must be < 2e-3)");
    assert!(diff < 2e-3);

    // --- numerics cross-check: sequential vs pipeline ----------------------
    println!("\n== numerics cross-check (monolithic vs staged) ==");
    let pipe = PipelineServer::new(Arc::clone(&engine), "deit_t", &StageAssign::spatial(), 1)?;
    let img = synth_images(1, 224, 12345);
    let a = seq.run_batch(1, &img)?;
    let (_, outs) = pipe.serve(vec![img])?;
    let max_diff = a
        .data
        .iter()
        .zip(&outs[0].data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!("  max |logit diff| = {max_diff:.2e} (must be < 2e-3)");
    assert!(max_diff < 2e-3);
    println!("  OK — stage composition is numerically faithful");
    Ok(())
}
