//! Quickstart: the SSR pipeline end to end, in one page.
//!
//! 1. Build the DeiT-T layer graph (paper Fig. 4).
//! 2. Evaluate the two pure strategies (sequential / spatial) on VCK190.
//! 3. Run the evolutionary Layer→Acc search (Algorithm 1) for the hybrid.
//! 4. Cross-check the winner on the event-driven simulator.
//!
//! Run with: `cargo run --release --example quickstart`

use ssr::analytical::{Calib, Features};
use ssr::arch::vck190;
use ssr::dse::ea::{run_ea, EaParams};
use ssr::dse::eval::build_design;
use ssr::dse::Assignment;
use ssr::graph::{vit_graph, DEIT_T};
use ssr::sim;

fn main() {
    let platform = vck190();
    let calib = Calib::default();
    let graph = vit_graph(&DEIT_T);
    println!(
        "DeiT-T: {} MM/BMM nodes, {:.2} GMACs/image, platform {} ({:.1} INT8 TOPS peak)\n",
        graph.node_count(),
        graph.macs_per_image as f64 / 1e9,
        platform.name,
        platform.peak_int8_tops()
    );

    let batch = 6;
    for (name, assignment) in [
        ("sequential (1 acc)", Assignment::sequential()),
        ("spatial   (8 accs)", Assignment::spatial()),
    ] {
        let ev = build_design(&platform, &calib, &graph, &assignment, Features::all(), true)
            .expect("feasible design");
        let e = ev.evaluate(&platform, &graph, batch);
        println!(
            "{name}: {:.3} ms latency, {:.2} TOPS, {:.0} GOPS/W (batch {batch})",
            e.latency_s * 1e3,
            e.tops,
            e.gops_per_w
        );
    }

    println!("\nrunning the evolutionary hybrid search (Algorithm 1)...");
    let params =
        EaParams { batch, n_pop: 16, n_child: 16, n_iter: 8, seed: 42, ..Default::default() };
    let result = run_ea(&platform, &calib, &graph, Features::all(), true, &params);
    let (ev, e) = result.best.expect("EA found a design");
    println!(
        "hybrid    ({} accs): {:.3} ms latency, {:.2} TOPS  — assignment {:?}",
        ev.design.assignment.nacc(),
        e.latency_s * 1e3,
        e.tops,
        ev.design.assignment.acc_of
    );

    let simres = sim::simulate(&platform, &ev, &graph, batch);
    println!(
        "simulator cross-check: {:.3} ms ({:+.1}% vs analytical)",
        simres.makespan_s * 1e3,
        (e.latency_s - simres.makespan_s) / simres.makespan_s * 100.0
    );
}
