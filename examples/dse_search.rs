//! DSE deep-dive (paper Sec. 4.4 + Fig. 10): run the inter-acc-aware
//! evolutionary search against the exhaustive baseline under a latency
//! constraint, print the search-quality trace and the winning design's
//! full configuration (Eq. 1 resources per accelerator).
//!
//! Run with: `cargo run --release --example dse_search [-- --quick]`

use ssr::analytical::{Calib, Features};
use ssr::arch::vck190;
use ssr::dse::ea::{run_ea, EaParams};
use ssr::dse::enumerate;
use ssr::dse::eval::build_design;
use ssr::graph::{vit_graph, DEIT_T};
use ssr::util::threadpool::{default_threads, scope_map};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let platform = vck190();
    let calib = Calib::default();
    let graph = vit_graph(&DEIT_T);
    let lat_cons = 2.0e-3;
    let batch = 6;

    println!("== inter-acc-aware EA (Algorithm 1 + Algorithm 2 pruning) ==");
    let params = EaParams {
        batch,
        lat_cons,
        n_pop: if quick { 8 } else { 24 },
        n_child: if quick { 8 } else { 24 },
        n_iter: if quick { 4 } else { 12 },
        seed: 0xEA,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let ea = run_ea(&platform, &calib, &graph, Features::all(), true, &params);
    let ea_secs = t0.elapsed().as_secs_f64();
    println!("search-quality trace (generation, best TOPS):");
    for (gen, tops) in &ea.trace {
        println!("  gen {gen:>2}  {tops:>6.2}");
    }
    let (ev, e) = ea.best.expect("feasible design");
    println!(
        "\nbest: {:?} -> {:.3} ms, {:.2} TOPS  ({} designs, {} configs, {:.2} s)",
        ev.design.assignment.acc_of,
        e.latency_s * 1e3,
        e.tops,
        ea.designs_evaluated,
        ea.configs_evaluated,
        ea_secs
    );
    println!("per-accelerator customization (config_vector, Eq. 1 resources):");
    for (i, c) in ev.design.configs.iter().enumerate() {
        println!(
            "  acc{i}: {:?}  h=({},{},{}) array=({},{},{})  AIE={} PLIO={} part={:?}",
            ev.design.assignment.classes_on(i),
            c.h1, c.w1, c.w2, c.a, c.b, c.c,
            c.aie(),
            c.plio(),
            c.part
        );
    }

    println!("\n== exhaustive baseline (post-verify, no alignment pruning) ==");
    let assignments = enumerate::all_up_to(8);
    let assignments = if quick {
        assignments.into_iter().step_by(32).collect::<Vec<_>>()
    } else {
        assignments
    };
    let t1 = std::time::Instant::now();
    let evals = scope_map(&assignments, default_threads(), |a| {
        build_design(&platform, &calib, &graph, a, Features::all(), false)
            .map(|ev| (ev.stats.configs_evaluated, ev.evaluate(&platform, &graph, batch)))
    });
    let ex_secs = t1.elapsed().as_secs_f64();
    let mut best = 0.0f64;
    let mut configs = 0usize;
    for r in evals.into_iter().flatten() {
        configs += r.0;
        if r.1.latency_s <= lat_cons {
            best = best.max(r.1.tops);
        }
    }
    println!(
        "exhaustive: best {best:.2} TOPS over {} assignments, {configs} configs, {ex_secs:.2} s",
        assignments.len()
    );
    println!(
        "\nsearch-cost ratio (exhaustive/EA): {:.1}x configs, {:.1}x wall",
        configs as f64 / ea.configs_evaluated as f64,
        ex_secs / ea_secs
    );
}
