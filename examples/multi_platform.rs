//! Cross-platform mapping (paper §6 Q1/Q2): apply the SSR analytical model
//! to VCK190, a hypothetical HBM VCK190, and Intel Stratix 10 NX; then the
//! multi-board scale-out estimate for a 16x model (DeiT-Base class).
//!
//! Run with: `cargo run --release --example multi_platform [-- --quick]`

use ssr::report::paper;
use ssr::report::tables::{self, Ctx};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");

    println!("== §6 Q1: SSR mapping DeiT-T (batch 6) on three platforms ==");
    println!("{:<14} {:>12} {:>10}", "platform", "latency(ms)", "TOPS");
    for r in tables::multi_platform(quick) {
        println!("{:<14} {:>12.3} {:>10.2}", r.platform, r.latency_ms, r.tops);
    }
    println!(
        "\npaper anchors: VCK190 0.54 ms, Stratix 10 NX {} ms, VCK190@102GB/s {} ms",
        paper::STRATIX_DEIT_T_MS,
        paper::VCK190_HBM_DEIT_T_MS
    );

    println!("\n== §6 Q2: DeiT-Base-class (16x params) over multiple boards ==");
    let ctx = if quick { Ctx::quick() } else { Ctx::vck190() };
    println!(
        "{:>7} {:>16} {:>18}",
        "boards", "b1 latency (ms)", "steady imgs/s"
    );
    for boards in [1usize, 2, 4, 8, 12, 16] {
        let (lat, thr) = tables::scaleout(&ctx, 16, boards, paper::SCALEOUT_HOP_MS);
        println!("{boards:>7} {lat:>16.2} {thr:>18.0}");
    }
    println!(
        "\n(paper assumes {} boards over 100Gb/s QSFP28 with {} ms hops)",
        paper::SCALEOUT_BOARDS,
        paper::SCALEOUT_HOP_MS
    );
}
