//! Pareto sweep (paper Fig. 2): regenerate the latency-throughput scatter
//! for DeiT-T on VCK190 — sequential trendline, spatial trendline, and the
//! SSR-hybrid points — and print the combined Pareto front.
//!
//! Run with: `cargo run --release --example pareto_sweep [-- --quick]`

use ssr::dse::pareto::front_dominates;
use ssr::report::tables::{self, Ctx};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let ctx = if quick { Ctx::quick() } else { Ctx::vck190() };
    let f = tables::fig2(&ctx);

    println!("{}", tables::fig2_table(&f).render());

    let front = f.hybrid_front();
    println!("combined SSR Pareto front (latency ms, TOPS):");
    for p in &front {
        println!(
            "  {:>7.3} ms  {:>6.2} TOPS   batch={} accs={}",
            p.latency_ms, p.tops, p.batch, p.nacc
        );
    }

    println!(
        "\nhybrid front dominates sequential-only: {}",
        front_dominates(&front, &f.seq)
    );
    println!(
        "hybrid front dominates spatial-only:    {}",
        front_dominates(&front, &f.spatial)
    );
    // Paper anchor points for eyeballing:
    println!("\npaper anchors: A(0.22, 10.90) B(1.30, 11.17) C(0.44, 5.66) D(0.58, 26.70) E(0.43, 18.56)");
}
