//! Example: build a latency-throughput front analytically and watch the
//! adaptive scheduler ride a rate ramp (no artifacts needed).
//!
//!     cargo run --release --example adaptive_sim
//!
//! This is the in-process version of the CLI flow:
//!
//!     ssr dse --emit-front front.json
//!     ssr simulate --front front.json --slo-ms 2 --ramp 1000:4000:8000:1000

use ssr::analytical::Calib;
use ssr::arch;
use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};
use ssr::dse::Assignment;
use ssr::graph::{vit_graph, DEIT_T};
use ssr::plan::front::analytical_front;
use ssr::sim::serving::serve_ramp;

fn main() {
    let platform = arch::vck190();
    let g = vit_graph(&DEIT_T);

    // Evaluate the paper's two pure strategies plus one hybrid across batch
    // sizes; analytical_front prunes the dominated points (Fig. 2 front).
    let candidates = vec![
        ("sequential".to_string(), Assignment::sequential()),
        ("spatial".to_string(), Assignment::spatial()),
        ("hybrid".to_string(), Assignment::new(vec![0, 1, 1, 1, 0, 2, 2, 0])),
    ];
    let front =
        analytical_front(&platform, &Calib::default(), &g, &candidates, &[1, 3, 6]).unwrap();
    print!("{}", front.describe());

    // Ramp through the regimes of Fig. 2 and replay the SLO scheduler.
    let ramp = RampSpec::parse("1000:4000:8000:4000:1000", 0.3).unwrap();
    let cfg = SchedulerCfg { slo_ms: 2.0, ..Default::default() };
    let report = serve_ramp(&front, &ramp, &cfg, 7);

    for s in &report.switches {
        println!(
            "switch @ {:.3} s: [{}] {} -> [{}] {} at {:.0} req/s observed",
            s.at_s,
            s.from,
            front.entries[s.from].label,
            s.to,
            front.entries[s.to].label,
            s.rate_rps
        );
    }
    println!("{}", report.summary_line());
}
