//! Bench: regenerate paper Table 5 (4 models x 3 batches x 4 platforms)
//! and report throughput/energy gains vs the paper's aggregate claims.

use ssr::bench::bench;
use ssr::report::paper;
use ssr::report::tables::{self, Ctx};
use ssr::util::stats::geomean;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let ctx = if quick { Ctx::quick() } else { Ctx::vck190() };
    let models: Vec<&str> = if quick {
        vec!["deit_t"]
    } else {
        vec!["deit_t", "deit_t_160", "deit_t_256", "lv_vit_t"]
    };

    let mut rows = None;
    let r = bench("table5: cross-platform sweep", 0, 1, 600.0, || {
        rows = Some(tables::table5(&ctx, &models));
    });
    println!("{}\n", r.report());
    let rows = rows.unwrap();
    println!("{}", tables::table5_table(&rows).render());

    // Aggregate gains (geomean across models x batches), as the paper does.
    let gains = |f: fn(&tables::Table5Row) -> f64| {
        geomean(&rows.iter().map(f).collect::<Vec<_>>())
    };
    let tg_gpu = gains(|r| r.ssr.tops / r.a10g.tops);
    let tg_z = gains(|r| r.ssr.tops / r.zcu102.tops);
    let tg_u = gains(|r| r.ssr.tops / r.u250.tops);
    let eg_gpu = gains(|r| r.ssr.gops_w / r.a10g.gops_w);
    let eg_z = gains(|r| r.ssr.gops_w / r.zcu102.gops_w);
    let eg_u = gains(|r| r.ssr.gops_w / r.u250.gops_w);
    println!("aggregate SSR gains (geomean)      measured   paper");
    println!("  throughput vs A10G            {tg_gpu:>9.2}x  {:>6.2}x", paper::AVG_THROUGHPUT_GAIN_VS_A10G);
    println!("  throughput vs ZCU102          {tg_z:>9.2}x  {:>6.2}x", paper::AVG_THROUGHPUT_GAIN_VS_ZCU102);
    println!("  throughput vs U250            {tg_u:>9.2}x  {:>6.2}x", paper::AVG_THROUGHPUT_GAIN_VS_U250);
    println!("  energy eff vs A10G            {eg_gpu:>9.2}x  {:>6.2}x", paper::AVG_ENERGY_GAIN_VS_A10G);
    println!("  energy eff vs ZCU102          {eg_z:>9.2}x  {:>6.2}x", paper::AVG_ENERGY_GAIN_VS_ZCU102);
    println!("  energy eff vs U250            {eg_u:>9.2}x  {:>6.2}x", paper::AVG_ENERGY_GAIN_VS_U250);
}
