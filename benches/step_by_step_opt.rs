//! Bench: regenerate paper §5.2.6 (step-by-step optimization analysis):
//! baseline (CHARM-like) -> +on-chip forwarding -> +spatial accs ->
//! +fine-grained pipeline, DeiT-T batch 6.

use ssr::bench::{bench, Table};
use ssr::report::paper;
use ssr::report::tables::{self, Ctx};

fn main() {
    let ctx = Ctx::vck190();

    let mut rows = None;
    let r = bench("step-by-step optimization", 0, 3, 20.0, || {
        rows = Some(tables::step_opt(&ctx, 6));
    });
    println!("{}\n", r.report());
    let rows = rows.unwrap();
    println!("{}", tables::step_table(&rows).render());

    let total = rows.first().unwrap().latency_ms / rows.last().unwrap().latency_ms;
    let paper_total = paper::STEP_BASELINE_MS / paper::STEP_FINAL_MS;
    let mut t = Table::new(&["metric", "paper", "measured"]);
    t.row(&[
        "baseline latency (ms)".to_string(),
        format!("{:.1}", paper::STEP_BASELINE_MS),
        format!("{:.2}", rows[0].latency_ms),
    ]);
    t.row(&[
        "final latency (ms)".to_string(),
        format!("{:.2}", paper::STEP_FINAL_MS),
        format!("{:.2}", rows[3].latency_ms),
    ]);
    t.row(&[
        "total speedup".to_string(),
        format!("{paper_total:.1}x"),
        format!("{total:.1}x"),
    ]);
    for (i, pf) in paper::STEP_FACTORS.iter().enumerate() {
        t.row(&[
            format!("step {} factor", i + 1),
            format!("{pf:.1}x"),
            format!("{:.2}x", rows[i + 1].factor),
        ]);
    }
    println!("{}", t.render());

    // Shape checks: every step helps, total speedup is large.
    for row in &rows[1..] {
        assert!(row.factor > 1.0, "step '{}' did not improve", row.name);
    }
    assert!(total > 5.0, "total step-opt speedup only {total:.1}x");
    println!("shape checks passed: every optimization step reduces latency");
}
