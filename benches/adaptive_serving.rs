//! Bench: adaptive serving of the latency-throughput front vs the two
//! fixed pure strategies (the serve-time analog of Table 6: instead of
//! picking one "best TOPS under a latency constraint" cell offline, the
//! scheduler re-picks it per load window).
//!
//! Sim-backed (analytical front + deterministic queueing replay), so it
//! runs without artifacts — CI uses `--quick --json BENCH_adaptive.json`
//! as the bounded perf-regression smoke.

use ssr::analytical::Calib;
use ssr::arch;
use ssr::bench::{bench, json_path_from_args, write_json, BenchResult, Table};
use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};
use ssr::dse::Assignment;
use ssr::graph::{vit_graph, DEIT_T};
use ssr::plan::front::{analytical_front, PlanFront};
use ssr::sim::serving::{serve_ramp, ServeSimReport};

/// The three canonical strategies as front candidates.
fn candidates() -> Vec<(String, Assignment)> {
    vec![
        ("sequential".to_string(), Assignment::sequential()),
        ("spatial".to_string(), Assignment::spatial()),
        ("hybrid".to_string(), Assignment::new(vec![0, 1, 1, 1, 0, 2, 2, 0])),
    ]
}

/// Analytical front restricted to one strategy (None = all of them).
fn front_of(label: Option<&str>, batches: &[usize]) -> PlanFront {
    let cands: Vec<(String, Assignment)> = candidates()
        .into_iter()
        .filter(|(l, _)| label.map(|want| l == want).unwrap_or(true))
        .collect();
    analytical_front(&arch::vck190(), &Calib::default(), &vit_graph(&DEIT_T), &cands, batches)
        .expect("non-empty front")
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let phase_s = if quick { 0.2 } else { 0.4 };
    // Ramp through the regimes of Fig. 2: low (sequential wins latency),
    // mid, and a rate only the spatial point's throughput can carry.
    let ramp = RampSpec::parse("1000:3500:8000:3500:1000", phase_s).unwrap();
    let cfg = SchedulerCfg { slo_ms: 2.0, ..Default::default() };
    let seed = 2024;

    let batches = [1, 3, 6];
    let policies: Vec<(&str, PlanFront)> = vec![
        ("sequential-only", front_of(Some("sequential"), &batches)),
        ("spatial-only", front_of(Some("spatial"), &batches)),
        ("adaptive (full front)", front_of(None, &batches)),
    ];

    let mut results: Vec<BenchResult> = Vec::new();
    let mut runs: Vec<(&str, ServeSimReport, usize)> = Vec::new();
    for (name, front) in &policies {
        let mut run = None;
        let r = bench(&format!("adaptive_serving: {name}"), 0, if quick { 1 } else { 3 }, 60.0, || {
            run = Some(serve_ramp(front, &ramp, &cfg, seed));
        });
        println!("{}", r.report());
        results.push(r);
        runs.push((*name, run.unwrap(), front.len()));
    }
    println!();

    let mut t = Table::new(&[
        "policy", "plans", "arrivals", "served", "shed", "p50 (ms)", "p99 (ms)", "SLO %",
        "switches",
    ]);
    for (name, r, plans) in &runs {
        t.row(&[
            name.to_string(),
            plans.to_string(),
            r.arrivals.to_string(),
            r.served.to_string(),
            r.shed.to_string(),
            format!("{:.3}", r.p50_ms()),
            format!("{:.3}", r.p99_ms()),
            format!("{:.1}", r.slo_attainment() * 100.0),
            r.switches.len().to_string(),
        ]);
    }
    println!("{}", t.render());

    // Structural claims mirroring the paper's tradeoff: every arrival is
    // accounted for; a fixed single-point policy cannot both carry the peak
    // and hold the low-load latency, while the adaptive front switches at
    // least once and serves at least as much as the pure-latency policy.
    for (name, r, _) in &runs {
        assert_eq!(r.served + r.shed, r.arrivals, "{name} lost requests");
    }
    let seq = &runs[0].1;
    let adaptive = &runs[2].1;
    assert!(
        !adaptive.switches.is_empty(),
        "adaptive policy never switched plans under the ramp"
    );
    assert!(
        adaptive.served >= seq.served,
        "adaptive ({}) served less than sequential-only ({})",
        adaptive.served,
        seq.served
    );
    println!(
        "structural checks passed: conservation, >=1 adaptive switch, adaptive >= fixed coverage"
    );

    if let Some(path) = json_path_from_args() {
        write_json(&path, &results).expect("write bench JSON");
        println!("wrote {}", path.display());
    }
}
