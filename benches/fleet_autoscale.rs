//! Bench: static peak provisioning vs closed-loop autoscaling on the same
//! bursty trace. The static fleet buys the forecast peak for the whole
//! run; the autoscaled fleet starts at the baseline provision and lets
//! the controller ride the burst (scale out from the pool, drain back in
//! after it). The claim under test: same trace, SLO held on the feasible
//! phases, strictly fewer device-seconds.
//!
//! Sim-backed (analytical fronts + deterministic replay), so it runs
//! without artifacts — CI uses `--quick --json BENCH_autoscale.json`.

use ssr::bench::{bench, json_path_from_args, write_json, BenchResult, Table};
use ssr::cluster::{
    provision, simulate_autoscale, simulate_fleet, AutoscaleCfg, AutoscaleReport,
    AutoscaleSpec, FaultSpec, FleetSimReport, PlatformOption, RoutePolicy, TrafficMix,
};
use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};

const SLO_MS: f64 = 25.0;
const HEADROOM: f64 = 0.8;
const BATCHES: [usize; 3] = [1, 3, 6];

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let phase_s = if quick { 0.2 } else { 0.4 };
    let seed = 2024;
    // Baseline 3k req/s with a 12k burst in the middle — the burst needs
    // two VCK190-class devices, the shoulders one.
    let trace = RampSpec::parse("3000:12000:12000:3000:3000", phase_s).unwrap();
    let cfg = SchedulerCfg { slo_ms: SLO_MS, ..Default::default() };
    let ctl = AutoscaleCfg { high_water: 0.85, low_water: 0.40, ..Default::default() };
    let options = [PlatformOption::synth("vck190", "deit_t", &BATCHES).expect("front")];

    // Static: size for the peak, pay for it the whole run.
    let peak = provision("static-peak", &options, &trace, SLO_MS, HEADROOM).expect("peak");
    // Autoscaled: size for the baseline, keep the peak delta in the pool.
    let baseline_fc = RampSpec::parse("3000", phase_s).unwrap();
    let base = provision("autoscaled", &options, &baseline_fc, SLO_MS, HEADROOM).expect("base");
    let pool = base.scale_pool(peak.devices.saturating_sub(base.devices).max(1));
    let spec = AutoscaleSpec {
        fleet: base.fleet.clone(),
        pool,
        faults: FaultSpec::none(),
        swap: None,
    };

    let mix = TrafficMix::single("deit_t", trace);
    let duration_s = mix.duration_s();
    let iters = if quick { 1 } else { 3 };
    let mut results: Vec<BenchResult> = Vec::new();

    let mut static_run: Option<FleetSimReport> = None;
    let r = bench("fleet_autoscale: static-peak", 0, iters, 60.0, || {
        static_run = Some(
            simulate_fleet(&peak.fleet, &mix, &cfg, RoutePolicy::PowerOfTwoSlo, seed)
                .expect("static fleet sim"),
        );
    });
    println!("{}", r.report());
    results.push(r);
    let static_run = static_run.unwrap();

    let mut auto_run: Option<AutoscaleReport> = None;
    let r = bench("fleet_autoscale: autoscaled", 0, iters, 60.0, || {
        auto_run = Some(
            simulate_autoscale(&spec, &mix, &cfg, &ctl, RoutePolicy::PowerOfTwoSlo, seed)
                .expect("autoscale sim"),
        );
    });
    println!("{}", r.report());
    results.push(r);
    let auto_run = auto_run.unwrap();
    println!();

    for e in &auto_run.events {
        println!("{}", e.describe());
    }
    let static_device_s = peak.devices as f64 * duration_s;
    let (sp50, sp99) = static_run.latency_ms();
    let (ap50, ap99) = auto_run.latency_ms();
    let mut t = Table::new(&[
        "fleet", "peak devs", "device-s", "arrivals", "served", "shed", "p50 (ms)",
        "p99 (ms)", "SLO %",
    ]);
    t.row(&[
        "static-peak".to_string(),
        peak.devices.to_string(),
        format!("{static_device_s:.2}"),
        static_run.arrivals.to_string(),
        static_run.served.to_string(),
        static_run.shed.to_string(),
        format!("{sp50:.3}"),
        format!("{sp99:.3}"),
        format!("{:.1}", static_run.slo_attainment() * 100.0),
    ]);
    t.row(&[
        "autoscaled".to_string(),
        auto_run.peak_live_devices().to_string(),
        format!("{:.2}", auto_run.device_seconds()),
        auto_run.arrivals.to_string(),
        auto_run.served.to_string(),
        auto_run.shed.to_string(),
        format!("{ap50:.3}"),
        format!("{ap99:.3}"),
        format!("{:.1}", auto_run.slo_attainment() * 100.0),
    ]);
    println!("{}", t.render());

    // Structural claims: conservation on both paths, and the autoscaled
    // fleet strictly undercuts static peak provisioning on device-time
    // without ever holding more devices than the static fleet bought.
    assert_eq!(
        static_run.served + static_run.shed,
        static_run.arrivals,
        "static fleet lost requests"
    );
    assert_eq!(
        auto_run.served + auto_run.shed,
        auto_run.arrivals,
        "autoscaled fleet lost requests"
    );
    assert!(
        auto_run.device_seconds() < static_device_s,
        "autoscaling spent {:.2} device-s, static peak {:.2}",
        auto_run.device_seconds(),
        static_device_s
    );
    assert!(auto_run.peak_live_devices() <= peak.devices);
    println!(
        "structural checks passed: conservation on both fleets; autoscaled {:.2} device-s < \
         static {static_device_s:.2}",
        auto_run.device_seconds()
    );

    if let Some(path) = json_path_from_args() {
        write_json(&path, &results).expect("write bench JSON");
        println!("wrote {}", path.display());
    }
}
