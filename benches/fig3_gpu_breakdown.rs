//! Bench: regenerate paper Fig. 3 (DeiT-T kernel breakdown on A10G,
//! batch 6) from the GPU baseline model.

use ssr::bench::bench;
use ssr::report::paper;
use ssr::report::tables;

fn main() {
    let mut out = None;
    let r = bench("fig3: gpu kernel breakdown", 1, 50, 5.0, || {
        out = Some(tables::fig3_table(6));
    });
    println!("{}\n", r.report());
    let (bd, table) = out.unwrap();
    println!("{}", table.render());

    println!("paper-vs-measured:");
    println!(
        "  total latency : paper {:.2} ms  measured {:.2} ms",
        paper::FIG3_TOTAL_MS,
        bd.total_s() * 1e3
    );
    println!(
        "  nonlinear share: paper ~{:.0}%  measured {:.1}%",
        paper::FIG3_NONLINEAR_SHARE * 100.0,
        bd.nonlinear_share() * 100.0
    );
    println!(
        "  transpose share: paper ~{:.0}%  measured {:.1}%",
        paper::FIG3_TRANSPOSE_SHARE * 100.0,
        bd.transpose_s / bd.total_s() * 100.0
    );
    println!(
        "  reformat share : paper ~{:.0}%  measured {:.1}%",
        paper::FIG3_REFORMAT_SHARE * 100.0,
        bd.reformat_s / bd.total_s() * 100.0
    );
}
