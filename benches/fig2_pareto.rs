//! Bench: regenerate paper Fig. 2 (latency-throughput Pareto, DeiT-T on
//! VCK190) and time the sweep. Prints model-vs-paper anchor comparison.

use ssr::bench::{bench, Table};
use ssr::dse::pareto::front_dominates;
use ssr::report::paper;
use ssr::report::tables::{self, Ctx};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let ctx = if quick { Ctx::quick() } else { Ctx::vck190() };

    let mut fig = None;
    let r = bench("fig2: full pareto sweep", 0, 1, 30.0, || {
        fig = Some(tables::fig2(&ctx));
    });
    println!("{}\n", r.report());
    let f = fig.unwrap();

    println!("{}", tables::fig2_table(&f).render());
    let front = f.hybrid_front();
    println!("combined Pareto front:");
    for p in &front {
        println!("  {:>7.3} ms  {:>6.2} TOPS  (batch {}, {} accs)", p.latency_ms, p.tops, p.batch, p.nacc);
    }

    // paper-vs-measured anchors
    let mut t = Table::new(&["anchor", "paper (ms, TOPS)", "measured (ms, TOPS)"]);
    let find = |pts: &[ssr::dse::pareto::Point], b: usize| {
        pts.iter().find(|p| p.batch == b).copied()
    };
    for (name, (pl, pt), got) in [
        ("seq b1 (A)", paper::FIG2_SEQ_A, find(&f.seq, 1)),
        ("seq b6 (B)", paper::FIG2_SEQ_B, find(&f.seq, 6)),
        ("spatial b6 (D)", paper::FIG2_SPATIAL_D, find(&f.spatial, 6)),
    ] {
        let m = got
            .map(|p| format!("({:.2}, {:.2})", p.latency_ms, p.tops))
            .unwrap_or_else(|| "-".into());
        t.row(&[name.to_string(), format!("({pl:.2}, {pt:.2})"), m]);
    }
    println!("\n{}", t.render());

    println!(
        "hybrid front dominates sequential: {} | dominates spatial: {}",
        front_dominates(&front, &f.seq),
        front_dominates(&front, &f.spatial)
    );
}
