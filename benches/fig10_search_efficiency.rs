//! Bench: regenerate paper Fig. 10 (search efficiency: inter-acc-aware
//! search vs exhaustive search under a 2 ms constraint).
//!
//! The paper's x-axis is wall-clock seconds on a 16-core Xeon; ours scales
//! to this machine, so the *ratio* and the quality-at-equal-budget are the
//! comparable quantities (paper: aware finds 26.70 TOPS in <1000 s,
//! exhaustive exceeds 4000 s without reaching it).

use ssr::report::tables::{self, Ctx};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let ctx = if quick { Ctx::quick() } else { Ctx::vck190() };

    let f = tables::fig10(&ctx, 6, 2.0e-3);
    println!("== Fig. 10: search efficiency (DeiT-T, latency <= 2 ms) ==\n");
    println!(
        "inter-acc-aware EA : {:>8.2} s  {:>9} configs  best {:>6.2} TOPS",
        f.aware_secs, f.aware_configs, f.aware_best_tops
    );
    println!(
        "exhaustive         : {:>8.2} s  {:>9} configs  best {:>6.2} TOPS",
        f.exhaustive_secs, f.exhaustive_configs, f.exhaustive_best_tops
    );
    println!(
        "\nsearch-cost ratio  : {:.1}x wall, {:.1}x configs (paper: >4x wall)",
        f.exhaustive_secs / f.aware_secs.max(1e-9),
        f.exhaustive_configs as f64 / f.aware_configs.max(1) as f64
    );
    println!(
        "quality            : aware reaches {:.1}% of exhaustive-best using {:.1}% of the configs",
        f.aware_best_tops / f.exhaustive_best_tops.max(1e-9) * 100.0,
        f.aware_configs as f64 / f.exhaustive_configs.max(1) as f64 * 100.0
    );
    assert!(f.aware_configs < f.exhaustive_configs);
    assert!(f.aware_best_tops >= 0.90 * f.exhaustive_best_tops,
            "aware search lost too much quality");
    println!("\nchecks passed: aware search is cheaper and near-optimal");
}
