//! Bench: regenerate paper Table 7 (analytical model vs "on-board"
//! latency per accelerator count). Our board substitute is the
//! event-driven simulator; the paper reports <5% error against silicon,
//! we report the analytical-vs-simulator residual.

use ssr::bench::{bench, Table};
use ssr::report::paper;
use ssr::report::tables::{self, Ctx};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let ctx = if quick { Ctx::quick() } else { Ctx::vck190() };

    let mut rows = None;
    let r = bench("table7: per-acc-count sweep", 0, 1, 300.0, || {
        rows = Some(tables::table7(&ctx, 6));
    });
    println!("{}\n", r.report());
    let rows = rows.unwrap();
    println!("{}", tables::table7_table(&rows).render());

    let mut t = Table::new(&["# accs", "paper est (ms)", "paper board (ms)", "our est (ms)", "our 'board' (ms)", "our err"]);
    for row in &rows {
        let paper_row = paper::TABLE7.iter().find(|(n, _, _)| *n == row.naccs);
        let (pe, pb) = paper_row.map(|(_, e, b)| (*e, *b)).unwrap_or((f64::NAN, f64::NAN));
        t.row(&[
            row.naccs.to_string(),
            format!("{pe:.2}"),
            format!("{pb:.2}"),
            format!("{:.3}", row.analytical_ms),
            format!("{:.3}", row.sim_ms),
            format!("{:+.1}%", row.err * 100.0),
        ]);
    }
    println!("{}", t.render());

    let max_err = rows.iter().map(|r| r.err.abs()).fold(0.0f64, f64::max);
    println!("max |analytical - sim| error: {:.1}% (paper reports <= 6% vs silicon)", max_err * 100.0);
    // Shape check: latency decreases as accelerators are added (1 -> max).
    assert!(rows.last().unwrap().sim_ms < rows.first().unwrap().sim_ms);
    println!("shape check passed: latency decreases with accelerator count");
}
