//! Bench: regenerate paper Table 6 (optimal TOPS under latency constraints
//! for GPU / SSR-sequential / SSR-spatial / SSR-hybrid, DeiT-T).

use ssr::bench::{bench, Table};
use ssr::report::paper;
use ssr::report::tables::{self, Ctx};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let ctx = if quick { Ctx::quick() } else { Ctx::vck190() };
    let constraints = [2.0, 1.0, 0.5, 0.4];

    let mut rows = None;
    let r = bench("table6: constraint sweep", 0, 1, 300.0, || {
        rows = Some(tables::table6(&ctx, &constraints));
    });
    println!("{}\n", r.report());
    let rows = rows.unwrap();
    println!("{}", tables::table6_table(&rows).render());

    // paper-vs-measured, cell by cell
    let fmt = |x: Option<f64>| x.map(|v| format!("{v:.2}")).unwrap_or_else(|| "x".into());
    let mut t = Table::new(&[
        "constraint", "GPU paper/ours", "seq paper/ours", "spatial paper/ours", "hybrid paper/ours",
    ]);
    for (row, (c, pg, ps, psp, ph)) in rows.iter().zip(paper::TABLE6) {
        assert_eq!(row.lat_cons_ms, c);
        t.row(&[
            format!("{c} ms"),
            format!("{}/{}", fmt(pg), fmt(row.gpu)),
            format!("{}/{}", fmt(ps), fmt(row.seq)),
            format!("{}/{}", fmt(psp), fmt(row.spatial)),
            format!("{}/{}", fmt(ph), fmt(row.hybrid)),
        ]);
    }
    println!("{}", t.render());

    // Structural claims: hybrid >= max(seq, spatial) everywhere; hybrid
    // feasible at the tightest constraint where spatial is not.
    for row in &rows {
        if let (Some(h), Some(s)) = (row.hybrid, row.seq) {
            assert!(h >= s - 1e-9, "hybrid below sequential at {}", row.lat_cons_ms);
        }
        if let (Some(h), Some(s)) = (row.hybrid, row.spatial) {
            assert!(h >= s - 1e-9, "hybrid below spatial at {}", row.lat_cons_ms);
        }
    }
    println!("structural checks passed: hybrid >= max(sequential, spatial) under every constraint");
}
