//! Bench: predictive vs reactive autoscaling on a flash-crowd trace.
//!
//! The same [`TraceSpec`] — a flash crowd climbing 10x above baseline in
//! under half a second — is served three ways: a static fleet sized for
//! the spike top, the reactive controller (scale out after `patience`
//! control intervals of observed overload), and the predictive controller
//! (`simulate_autoscale_predictive`: a Holt forecast of the arrival rate
//! pre-warms capacity as soon as the *projected* rate breaches the high
//! water mark). The claims under test, at equal seeds and equal pools:
//! the forecast's lead time converts directly into strictly fewer shed
//! requests than the reactive run, and both autoscaled runs undercut
//! static peak provisioning on device-seconds.
//!
//! Sim-backed (explicit fronts + deterministic replay), so it runs
//! without artifacts — CI uses `--quick --json BENCH_trace.json`.

use ssr::bench::{bench, json_path_from_args, write_json, BenchResult, Table};
use ssr::cluster::{
    simulate_autoscale, simulate_autoscale_predictive, simulate_fleet, AutoscaleCfg,
    AutoscaleReport, AutoscaleSpec, DeviceSpec, FaultSpec, FleetSpec, ForecastCfg,
    RoutePolicy,
};
use ssr::coordinator::scheduler::SchedulerCfg;
use ssr::plan::front::{FrontEntry, PlanFront};
use ssr::traffic::{ArrivalProcess, RateCurve, TraceSpec};

const SLO_MS: f64 = 25.0;
const HEADROOM: f64 = 0.8;
const SEQ_RPS: f64 = 5000.0;
const SPATIAL_RPS: f64 = 12000.0;

fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
    FrontEntry {
        assign: vec![0; 8],
        batch,
        latency_ms: lat_ms,
        tops: rps * 2.5e-3,
        rps,
        nacc: 1,
        label: label.to_string(),
    }
}

fn front() -> PlanFront {
    PlanFront::new(
        "deit_t",
        12,
        vec![entry("seq", 1, 0.2, SEQ_RPS), entry("spatial", 24, 2.0, SPATIAL_RPS)],
    )
    .expect("front")
}

fn dev(id: &str) -> DeviceSpec {
    DeviceSpec { id: id.to_string(), platform: "vck190".to_string(), front: front() }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let seed = 2025;
    // Baseline 3k req/s, flash crowd to 30k at t = 0.7 s: one device rides
    // the baseline, the spike needs the whole pool.
    let trace = TraceSpec::single(
        "deit_t",
        RateCurve::Flash {
            base_rps: 3000.0,
            peak_rps: 30000.0,
            at_s: 0.7,
            ramp_s: 0.4,
            decay_s: 0.3,
            duration_s: 3.0,
        },
        ArrivalProcess::Poisson,
    );
    let duration_s = trace.duration_s();
    let cfg = SchedulerCfg { slo_ms: SLO_MS, ..Default::default() };
    let ctl = AutoscaleCfg { high_water: 0.85, low_water: 0.40, ..Default::default() };
    let forecast = ForecastCfg::default();

    // Static: buy the spike top (peak rate at target utilization) for the
    // whole run.
    let static_devices =
        (trace.peak_rps() / (HEADROOM * SPATIAL_RPS)).ceil().max(1.0) as usize;
    let static_fleet = FleetSpec::new(
        "static-peak",
        (0..static_devices).map(|i| dev(&format!("s{i}"))).collect(),
    )
    .expect("static fleet");
    // Autoscaled: one baseline device, the spike delta waits in the pool.
    let spec = AutoscaleSpec {
        fleet: FleetSpec::new("autoscaled", vec![dev("d0")]).expect("fleet"),
        pool: (0..static_devices - 1).map(|i| dev(&format!("p{i}"))).collect(),
        faults: FaultSpec::none(),
        swap: None,
    };

    let iters = if quick { 1 } else { 3 };
    let mut results: Vec<BenchResult> = Vec::new();

    let mut static_run = None;
    let r = bench("trace_serving: static-peak", 0, iters, 60.0, || {
        static_run = Some(
            simulate_fleet(&static_fleet, &trace, &cfg, RoutePolicy::RoundRobin, seed)
                .expect("static fleet sim"),
        );
    });
    println!("{}", r.report());
    results.push(r);
    let static_run = static_run.unwrap();

    let mut reactive_run: Option<AutoscaleReport> = None;
    let r = bench("trace_serving: reactive", 0, iters, 60.0, || {
        reactive_run = Some(
            simulate_autoscale(&spec, &trace, &cfg, &ctl, RoutePolicy::RoundRobin, seed)
                .expect("reactive sim"),
        );
    });
    println!("{}", r.report());
    results.push(r);
    let reactive_run = reactive_run.unwrap();

    let mut predictive_run: Option<AutoscaleReport> = None;
    let r = bench("trace_serving: predictive", 0, iters, 60.0, || {
        predictive_run = Some(
            simulate_autoscale_predictive(
                &spec,
                &trace,
                &cfg,
                &ctl,
                &forecast,
                RoutePolicy::RoundRobin,
                seed,
            )
            .expect("predictive sim"),
        );
    });
    println!("{}", r.report());
    results.push(r);
    let predictive_run = predictive_run.unwrap();
    println!();

    print!("{}", trace.describe());
    println!("reactive control events:");
    for e in &reactive_run.events {
        println!("  {}", e.describe());
    }
    println!("predictive control events:");
    for e in &predictive_run.events {
        println!("  {}", e.describe());
    }

    let static_device_s = static_devices as f64 * duration_s;
    let (sp50, sp99) = static_run.latency_ms();
    let (rp50, rp99) = reactive_run.latency_ms();
    let (pp50, pp99) = predictive_run.latency_ms();
    let mut t = Table::new(&[
        "fleet", "peak devs", "device-s", "arrivals", "served", "shed", "p50 (ms)",
        "p99 (ms)", "SLO %",
    ]);
    t.row(&[
        "static-peak".to_string(),
        static_devices.to_string(),
        format!("{static_device_s:.2}"),
        static_run.arrivals.to_string(),
        static_run.served.to_string(),
        static_run.shed.to_string(),
        format!("{sp50:.3}"),
        format!("{sp99:.3}"),
        format!("{:.1}", static_run.slo_attainment() * 100.0),
    ]);
    t.row(&[
        "reactive".to_string(),
        reactive_run.peak_live_devices().to_string(),
        format!("{:.2}", reactive_run.device_seconds()),
        reactive_run.arrivals.to_string(),
        reactive_run.served.to_string(),
        reactive_run.shed.to_string(),
        format!("{rp50:.3}"),
        format!("{rp99:.3}"),
        format!("{:.1}", reactive_run.slo_attainment() * 100.0),
    ]);
    t.row(&[
        "predictive".to_string(),
        predictive_run.peak_live_devices().to_string(),
        format!("{:.2}", predictive_run.device_seconds()),
        predictive_run.arrivals.to_string(),
        predictive_run.served.to_string(),
        predictive_run.shed.to_string(),
        format!("{pp50:.3}"),
        format!("{pp99:.3}"),
        format!("{:.1}", predictive_run.slo_attainment() * 100.0),
    ]);
    println!("{}", t.render());

    // Structural claims. Conservation everywhere; identical arrival
    // streams across the three runs (same seed, same per-class RNG
    // streams); the forecast's pre-warm sheds strictly less than the
    // reactive controller; and both autoscaled fleets undercut static
    // peak provisioning on device-time.
    assert_eq!(
        static_run.served + static_run.shed,
        static_run.arrivals,
        "static fleet lost requests"
    );
    for (name, run) in [("reactive", &reactive_run), ("predictive", &predictive_run)] {
        assert_eq!(
            run.served + run.shed,
            run.arrivals,
            "{name} fleet lost requests"
        );
        assert_eq!(run.arrivals, static_run.arrivals, "{name} saw a different trace");
        assert!(
            run.device_seconds() < static_device_s,
            "{name} spent {:.2} device-s, static peak {static_device_s:.2}",
            run.device_seconds()
        );
    }
    assert!(
        predictive_run.shed < reactive_run.shed,
        "predictive pre-warm shed {} >= reactive {}",
        predictive_run.shed,
        reactive_run.shed
    );
    println!(
        "structural checks passed: conservation on all fleets; predictive shed {} < \
         reactive {}; both autoscaled < static {static_device_s:.2} device-s",
        predictive_run.shed, reactive_run.shed
    );

    if let Some(path) = json_path_from_args() {
        write_json(&path, &results).expect("write bench JSON");
        println!("wrote {}", path.display());
    }
}
