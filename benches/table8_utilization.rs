//! Bench: regenerate paper Table 8 (SSR-spatial resource utilization for
//! DeiT-T): per-accelerator Eq. 1 resources and platform totals.

use ssr::bench::bench;
use ssr::report::paper;
use ssr::report::tables::{self, Ctx};

fn main() {
    let ctx = Ctx::vck190();

    let mut out = None;
    let r = bench("table8: spatial design resources", 0, 5, 20.0, || {
        out = Some(tables::table8(&ctx));
    });
    println!("{}\n", r.report());
    let t8 = out.unwrap();
    println!("{}", tables::table8_table(&t8, &ctx.platform).render());

    let p = &paper::TABLE8_TOTAL;
    println!("paper totals: AIE {} PLIO {} BRAM {} DSP {}", p.aie, p.plio, p.bram, p.dsp);
    println!(
        "our totals  : AIE {} PLIO {} BRAM banks {} DSP {}",
        t8.aie, t8.plio, t8.bram_banks, t8.dsp
    );
    println!(
        "AIE utilization: paper {:.1}%  ours {:.1}%",
        p.aie as f64 / 400.0 * 100.0,
        t8.aie as f64 / ctx.platform.aie_total as f64 * 100.0
    );
    assert!(t8.aie <= ctx.platform.aie_total);
    assert!(t8.plio <= ctx.platform.plio_total);
    println!("resource-fit checks passed");
}
