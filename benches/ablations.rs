//! Ablation bench (DESIGN.md design-choice ablations, beyond the paper's
//! §5.2.6 step analysis): isolate each SSR mechanism on DeiT-T, batch 6.
//!
//! * inter-acc-aware co-design ON vs OFF (repack penalties post-paid),
//! * fine-grained pipeline ON vs OFF,
//! * stage-equalizing rebalance implicitly (spatial with/without is shown
//!   via the aware/naive gap),
//! * weight pinning: sequential acc forced to HMM-type1 by co-locating
//!   attention (the pinning flag is assignment-derived).

use ssr::analytical::{Calib, Features};
use ssr::arch::vck190;
use ssr::bench::Table;
use ssr::dse::eval::build_design;
use ssr::dse::Assignment;
use ssr::graph::{vit_graph, DEIT_T};

fn main() {
    let p = vck190();
    let cal = Calib::default();
    let g = vit_graph(&DEIT_T);
    let batch = 6;
    let mut t = Table::new(&["ablation", "variant", "latency (ms)", "TOPS"]);

    let eval = |a: &Assignment, f: Features, aware: bool| {
        let ev = build_design(&p, &cal, &g, a, f, aware).expect("feasible");
        ev.evaluate(&p, &g, batch)
    };

    // 1) inter-acc-aware co-design (force partition + alignment pruning)
    for (variant, aware) in [("co-design ON", true), ("co-design OFF (repack)", false)] {
        let e = eval(&Assignment::spatial(), Features::all(), aware);
        t.row(&[
            "inter-acc co-design".to_string(),
            variant.to_string(),
            format!("{:.3}", e.latency_s * 1e3),
            format!("{:.2}", e.tops),
        ]);
    }

    // 2) fine-grained pipeline
    for (variant, fp) in [("pipeline ON", true), ("pipeline OFF", false)] {
        let e = eval(
            &Assignment::spatial(),
            Features { fine_grained_pipeline: fp, ..Features::all() },
            true,
        );
        t.row(&[
            "fine-grained pipeline".to_string(),
            variant.to_string(),
            format!("{:.3}", e.latency_s * 1e3),
            format!("{:.2}", e.tops),
        ]);
    }

    // 3) weight pinning: isolate the attention classes (pinning available
    //    on the non-attention acc) vs co-locating them everywhere (pinning
    //    impossible anywhere it matters).
    let pin_friendly = Assignment::new(vec![0, 0, 1, 1, 0, 0, 0, 0]);
    let pin_hostile = Assignment::new(vec![0, 1, 0, 1, 0, 1, 0, 1]);
    for (variant, a) in
        [("attention isolated (pinning ON)", &pin_friendly), ("attention mixed in (pinning OFF)", &pin_hostile)]
    {
        let e = eval(a, Features::all(), true);
        t.row(&[
            "weight pinning".to_string(),
            variant.to_string(),
            format!("{:.3}", e.latency_s * 1e3),
            format!("{:.2}", e.tops),
        ]);
    }

    println!("== Ablations (DeiT-T, batch 6, VCK190) ==\n");
    println!("{}", t.render());

    // Structural expectations.
    let aware = eval(&Assignment::spatial(), Features::all(), true);
    let naive = eval(&Assignment::spatial(), Features::all(), false);
    assert!(aware.latency_s <= naive.latency_s * 1.001, "co-design should not hurt");
    let pin_on = eval(&pin_friendly, Features::all(), true);
    let pin_off = eval(&pin_hostile, Features::all(), true);
    assert!(
        pin_on.tops >= pin_off.tops * 0.95,
        "isolating attention should not lose throughput: {} vs {}",
        pin_on.tops,
        pin_off.tops
    );
    println!("structural checks passed");
}
