//! Bench: input-dynamic serving — stochastic service times vs the
//! scheduler's tail awareness.
//!
//! The same steady 4.2 k req/s workload is served four ways: service
//! times deterministic or heavy-tailed (a sigma-2 lognormal launch
//! factor, mean-preserving so every row offers identical *mean* load),
//! crossed with the mean-based and the p99-aware plan scheduler. The
//! claim under test (ISSUE 10 acceptance): sizing plan switches for the
//! observed p99 instead of the mean converts directly into strictly
//! fewer shed requests on the heavy-tail workload at the same SLO —
//! the mean-based scheduler parks on the 6 k hybrid plan and drowns in
//! tail-length launches, while the p99-aware one escalates to the 12 k
//! spatial plan whose deeper admission budget absorbs the same tail.
//!
//! Sim-backed (explicit front + deterministic replay), so it runs
//! without artifacts — CI uses `--quick --json BENCH_dynamic.json`.

use ssr::bench::{bench, json_path_from_args, write_json, BenchResult, Table};
use ssr::cluster::TrafficMix;
use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};
use ssr::plan::front::{FrontEntry, PlanFront};
use ssr::sim::serving::{serve_ramp, ServeSimReport};
use ssr::sim::service::ServiceModel;
use ssr::traffic::TraceSpec;

const SLO_MS: f64 = 5.0;
const SEED: u64 = 42;

fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
    FrontEntry {
        assign: vec![0; 8],
        batch,
        latency_ms: lat_ms,
        tops: rps * 2.5e-3,
        rps,
        nacc: 1,
        label: label.to_string(),
    }
}

fn front() -> PlanFront {
    PlanFront::new(
        "deit_t",
        12,
        vec![
            entry("seq", 1, 0.2, 5000.0),
            entry("hybrid", 6, 1.0, 6000.0),
            entry("spatial", 24, 2.0, 12000.0),
        ],
    )
    .expect("front")
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    // 2.4 s at a steady 4.2 k req/s: demand 4200/0.8 = 5250 sits between
    // the hybrid plan's 6 k nominal rate and what it can actually sustain
    // once the tail factor stretches launches.
    let ramp = RampSpec::parse("4200:4200:4200:4200", 0.6).expect("ramp");
    let mix = TrafficMix::single("deit_t", ramp);
    let det = TraceSpec::from(&mix);
    let noisy = det.clone().with_service(&ServiceModel::LognormalFactor { sigma: 2.0 });
    let mean_cfg = SchedulerCfg { slo_ms: SLO_MS, ..Default::default() };
    let p99_cfg = SchedulerCfg { slo_ms: SLO_MS, p99_aware: true, ..Default::default() };

    let iters = if quick { 1 } else { 3 };
    let rows: [(&str, &TraceSpec, &SchedulerCfg); 4] = [
        ("det / mean", &det, &mean_cfg),
        ("det / p99", &det, &p99_cfg),
        ("noisy / mean", &noisy, &mean_cfg),
        ("noisy / p99", &noisy, &p99_cfg),
    ];

    let mut results: Vec<BenchResult> = Vec::new();
    let mut runs: Vec<ServeSimReport> = Vec::new();
    for (name, trace, cfg) in rows {
        let mut run = None;
        let r = bench(&format!("dynamic_serving: {name}"), 0, iters, 60.0, || {
            run = Some(serve_ramp(&front(), (*trace).clone(), cfg, SEED));
        });
        println!("{}", r.report());
        results.push(r);
        runs.push(run.unwrap());
    }
    println!();

    let mut t = Table::new(&[
        "service / scheduler", "arrivals", "served", "shed", "switches", "p50 (ms)", "p99 (ms)",
    ]);
    for ((name, _, _), run) in rows.iter().zip(&runs) {
        let p = run.latency.percentiles(&[0.50, 0.99]);
        t.row(&[
            name.to_string(),
            run.arrivals.to_string(),
            run.served.to_string(),
            run.shed.to_string(),
            run.switches.len().to_string(),
            format!("{:.3}", p[0] * 1e3),
            format!("{:.3}", p[1] * 1e3),
        ]);
    }
    println!("{}", t.render());

    // Structural claims. Conservation on every row; identical arrival
    // streams (the service stream is split off the arrival streams, so
    // neither noise nor the policy can perturb what's offered); and the
    // headline tradeoff — on heavy tails the p99-aware scheduler sheds
    // strictly fewer requests than the mean-based one at the same SLO.
    for ((name, _, _), run) in rows.iter().zip(&runs) {
        assert_eq!(run.served + run.shed, run.arrivals, "{name}: lost requests");
        assert_eq!(run.arrivals, runs[0].arrivals, "{name}: saw a different workload");
    }
    let (noisy_mean, noisy_p99) = (&runs[2], &runs[3]);
    assert!(
        noisy_mean.shed > 0,
        "heavy-tail workload must stress the mean-based scheduler (shed {})",
        noisy_mean.shed
    );
    assert!(
        noisy_p99.shed < noisy_mean.shed,
        "p99-aware shed {} >= mean-based {}",
        noisy_p99.shed,
        noisy_mean.shed
    );
    println!(
        "structural checks passed: conservation on all rows; p99-aware shed {} < \
         mean-based {} on the heavy-tail workload",
        noisy_p99.shed, noisy_mean.shed
    );

    if let Some(path) = json_path_from_args() {
        write_json(&path, &results).expect("write bench JSON");
        println!("wrote {}", path.display());
    }
}
