//! Bench: fleet serving under one traffic ramp — homogeneous seq-only vs
//! homogeneous spatial-only vs the provisioned heterogeneous hybrid
//! fleet. The fleet-scale version of the adaptive-serving bench: instead
//! of one device switching plans, the provisioner picks a platform mix
//! and every device runs its own adaptive scheduler behind the router.
//!
//! Sim-backed (analytical fronts + deterministic fleet replay), so it
//! runs without artifacts — CI uses `--quick --json BENCH_cluster.json`.

use ssr::bench::{bench, json_path_from_args, write_json, BenchResult, Table};
use ssr::cluster::fleet::strategy_front;
use ssr::cluster::{
    provision, simulate_fleet, FleetSimReport, PlatformOption, ProvisionResult, RoutePolicy,
    TrafficMix,
};
use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};

const SLO_MS: f64 = 25.0;
const HEADROOM: f64 = 0.8;
const BATCHES: [usize; 3] = [1, 3, 6];

fn homogeneous(strategy: &str) -> Vec<PlatformOption> {
    vec![PlatformOption {
        platform: "vck190".to_string(),
        front: strategy_front("vck190", "deit_t", strategy, &BATCHES).expect("strategy front"),
    }]
}

fn heterogeneous() -> Vec<PlatformOption> {
    ["vck190", "stratix10nx", "zcu102", "u250"]
        .into_iter()
        .map(|p| PlatformOption::synth(p, "deit_t", &BATCHES).expect("platform front"))
        .collect()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let phase_s = if quick { 0.2 } else { 0.4 };
    // Forecast peaking at 12k req/s — several times one VCK190's
    // sequential-point capacity, under two spatial points.
    let forecast = RampSpec::parse("3000:8000:12000:8000:3000", phase_s).unwrap();
    let cfg = SchedulerCfg { slo_ms: SLO_MS, ..Default::default() };
    let seed = 2024;

    let size = |name: &str, options: &[PlatformOption]| {
        provision(name, options, &forecast, SLO_MS, HEADROOM).expect("provisioning")
    };
    let fleets: Vec<(&str, ProvisionResult)> = vec![
        ("seq-only", size("seq-only", &homogeneous("sequential"))),
        ("spatial-only", size("spatial-only", &homogeneous("spatial"))),
        ("het-hybrid", size("het-hybrid", &heterogeneous())),
    ];

    let mix = TrafficMix::single("deit_t", forecast);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut runs: Vec<(&str, &ProvisionResult, FleetSimReport)> = Vec::new();
    for (name, p) in &fleets {
        let mut run = None;
        let r = bench(
            &format!("cluster_serving: {name}"),
            0,
            if quick { 1 } else { 3 },
            60.0,
            || {
                run = Some(
                    simulate_fleet(&p.fleet, &mix, &cfg, RoutePolicy::PowerOfTwoSlo, seed)
                        .expect("fleet sim"),
                );
            },
        );
        println!("{}", r.report());
        results.push(r);
        runs.push((*name, p, run.unwrap()));
    }
    println!();

    let mut t = Table::new(&[
        "fleet", "devices", "power (W)", "arrivals", "served", "shed", "p50 (ms)",
        "p99 (ms)", "SLO %", "switches",
    ]);
    for (name, p, r) in &runs {
        let (p50, p99) = r.latency_ms();
        t.row(&[
            name.to_string(),
            p.devices.to_string(),
            format!("{:.1}", p.power_w),
            r.arrivals.to_string(),
            r.served.to_string(),
            r.shed.to_string(),
            format!("{:.3}", p50),
            format!("{:.3}", p99),
            format!("{:.1}", r.slo_attainment() * 100.0),
            r.total_switches().to_string(),
        ]);
    }
    println!("{}", t.render());

    // Structural claims, fleet-scale: every arrival is accounted for on
    // every fleet, and the heterogeneous hybrid provisioning needs no
    // more devices than either homogeneous corner (no more power on a
    // device-count tie).
    for (name, _, r) in &runs {
        assert_eq!(r.served + r.shed, r.arrivals, "{name} lost requests");
    }
    let (seq, spa, het) = (&runs[0].1, &runs[1].1, &runs[2].1);
    assert!(
        het.devices <= seq.devices && het.devices <= spa.devices,
        "het {} devices vs seq {} / spatial {}",
        het.devices,
        seq.devices,
        spa.devices
    );
    if het.devices == spa.devices {
        assert!(
            het.power_w <= spa.power_w + 1e-9 || het.capacity_rps > spa.capacity_rps + 1e-9,
            "het {} W > spatial-only {} W at equal devices and no capacity gain",
            het.power_w,
            spa.power_w
        );
    }
    println!(
        "structural checks passed: conservation on all fleets; het-hybrid <= homogeneous \
         on devices (power on ties)"
    );

    if let Some(path) = json_path_from_args() {
        write_json(&path, &results).expect("write bench JSON");
        println!("wrote {}", path.display());
    }
}
