//! Bench: the fast sim core. Measures the indexed-calendar event loop on
//! its O(1)-memory path (streaming arrivals + latency sketch, no
//! per-request allocations) and the sharded parallel sweep, and proves the
//! memory claim with an allocation-counting global allocator: driving 4x
//! the requests through the sketched replay must not grow heap traffic
//! anywhere near 4x.
//!
//! Sim-backed (synthetic front + deterministic replay), so it runs without
//! artifacts — CI uses `--quick --json BENCH_simcore.json`. Perf numbers
//! (events/s, replayed req/s, allocation tallies) are record-only: CI
//! tracks the artifact per commit, it does not gate on absolute
//! throughput. The committed single-core target is 10M simulated req/s
//! (`target_req_per_s` in the metrics block).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use ssr::bench::{bench, json_path_from_args, write_json_with_metrics, BenchResult, Table};
use ssr::coordinator::scheduler::{ArrivalStream, RampSpec, SchedulerCfg, TrafficMix};
use ssr::obs::{TraceEvent, TraceRecorder};
use ssr::plan::front::{FrontEntry, PlanFront};
use ssr::sim::device::{
    run_timeline_sketched, run_timeline_sketched_recorded, DeviceSim, NoControl, SketchOutcome,
};
use ssr::sim::sweep::{run_sweep, SweepCfg};

// ---------------------------------------------------------------------------
// Counting allocator: peak-RSS proxy without OS-specific rusage plumbing.
// ---------------------------------------------------------------------------

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);
static LIVE_BYTES: AtomicU64 = AtomicU64::new(0);
static PEAK_LIVE_BYTES: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

fn on_alloc(bytes: u64) {
    ALLOC_CALLS.fetch_add(1, Relaxed);
    ALLOC_BYTES.fetch_add(bytes, Relaxed);
    let live = LIVE_BYTES.fetch_add(bytes, Relaxed) + bytes;
    PEAK_LIVE_BYTES.fetch_max(live, Relaxed);
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            on_alloc(layout.size() as u64);
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            on_alloc(layout.size() as u64);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE_BYTES.fetch_sub(layout.size() as u64, Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            // Count the grown tail as fresh traffic; shrinks release live.
            if new_size > layout.size() {
                on_alloc((new_size - layout.size()) as u64);
            } else {
                LIVE_BYTES.fetch_sub((layout.size() - new_size) as u64, Relaxed);
            }
        }
        p
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Heap traffic (allocated bytes) across `f`, on a quiesced single thread.
fn alloc_bytes_during<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = ALLOC_BYTES.load(Relaxed);
    let r = f();
    (ALLOC_BYTES.load(Relaxed) - before, r)
}

// ---------------------------------------------------------------------------
// Workload: synthetic front, single-class Poisson ramp.
// ---------------------------------------------------------------------------

fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
    FrontEntry {
        assign: vec![0; 8],
        batch,
        latency_ms: lat_ms,
        tops: rps * 2.5e-3,
        rps,
        nacc: 1,
        label: label.to_string(),
    }
}

fn front() -> PlanFront {
    PlanFront::new(
        "synthetic",
        12,
        vec![
            entry("seq", 1, 0.2, 5000.0),
            entry("hybrid", 6, 1.0, 6000.0),
            entry("spatial", 24, 2.0, 12000.0),
        ],
    )
    .unwrap()
}

/// One sketched single-device replay of `rate` req/s over `duration_s`.
fn sketched_replay(
    front: &PlanFront,
    cfg: &SchedulerCfg,
    rate: f64,
    duration_s: f64,
    seed: u64,
) -> SketchOutcome {
    let ramp = RampSpec { rates_rps: vec![rate], phase_s: duration_s };
    let mix = TrafficMix::single(&front.model, ramp);
    let mut stream = ArrivalStream::new(&mix, seed);
    let mut devs = vec![DeviceSim::new(front.clone(), *cfg).without_latency_samples()];
    run_timeline_sketched(
        &mut devs,
        &mut stream,
        mix.duration_s(),
        cfg.window_s,
        |_, _, _| Some(0),
        &mut NoControl,
    )
}

/// The same replay with a live [`TraceRecorder`] collecting every event —
/// the opt-in observability path whose overhead the bench reports.
fn sketched_replay_traced(
    front: &PlanFront,
    cfg: &SchedulerCfg,
    rate: f64,
    duration_s: f64,
    seed: u64,
) -> (SketchOutcome, Vec<TraceEvent>) {
    let ramp = RampSpec { rates_rps: vec![rate], phase_s: duration_s };
    let mix = TrafficMix::single(&front.model, ramp);
    let mut stream = ArrivalStream::new(&mix, seed);
    let mut devs = vec![DeviceSim::new(front.clone(), *cfg).without_latency_samples()];
    let mut rec = TraceRecorder::new();
    let out = run_timeline_sketched_recorded(
        &mut devs,
        &mut stream,
        mix.duration_s(),
        cfg.window_s,
        |_, _, _| Some(0),
        &mut NoControl,
        &mut rec,
    );
    (out, rec.into_events())
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let front = front();
    let cfg = SchedulerCfg { slo_ms: 20.0, ..Default::default() };
    let seed = 2026;
    let duration_s = if quick { 0.5 } else { 2.0 };
    // Well past the front's service capacity, so every event class
    // (serve, shed, window tick) stays hot in the loop.
    let rate = 40_000.0;
    let iters = if quick { 3 } else { 10 };
    let mut results: Vec<BenchResult> = Vec::new();
    let mut metrics: Vec<(String, f64)> = Vec::new();

    // -- single-core sketched replay ---------------------------------------
    let mut out: Option<SketchOutcome> = None;
    let r = bench("simcore: sketched replay (1 core)", 1, iters, 30.0, || {
        out = Some(sketched_replay(&front, &cfg, rate, duration_s, seed));
    });
    println!("{}", r.report());
    let out = out.unwrap();
    let events_per_s = out.events as f64 / r.mean_s;
    let req_per_s = out.arrivals as f64 / r.mean_s;
    metrics.push(("events_per_s".to_string(), events_per_s));
    metrics.push(("req_per_s".to_string(), req_per_s));
    metrics.push(("target_req_per_s".to_string(), 10e6));
    results.push(r);

    // -- sharded sweep across the thread pool ------------------------------
    let sweep_cfg = SweepCfg {
        seeds: if quick { 2 } else { 4 },
        shards: if quick { 4 } else { 8 },
        threads: 0,
        exact: false,
    };
    let ramp = RampSpec { rates_rps: vec![rate], phase_s: duration_s };
    let mut sweep_events = 0u64;
    let mut sweep_arrivals = 0usize;
    let r = bench("simcore: sharded sweep (all cores)", 0, iters.min(5), 30.0, || {
        let sr = run_sweep(&front, &ramp, &cfg, &sweep_cfg, seed);
        assert_eq!(sr.served + sr.shed, sr.arrivals, "sweep lost requests");
        sweep_events = sr.events;
        sweep_arrivals = sr.arrivals;
    });
    println!("{}", r.report());
    metrics.push(("sweep_events_per_s".to_string(), sweep_events as f64 / r.mean_s));
    metrics.push(("sweep_req_per_s".to_string(), sweep_arrivals as f64 / r.mean_s));
    results.push(r);

    // -- O(1)-memory claim: 4x the requests, flat heap traffic -------------
    // Same wall-clock span (so window/report structures are identical),
    // 4x the offered rate: total requests scale ~4x while the sketched
    // path's heap traffic must stay roughly flat (stream state, sketch
    // bins, and recycled launch buffers are all fixed-size).
    let lo_rate = 10_000.0;
    let (lo_bytes, lo_out) =
        alloc_bytes_during(|| sketched_replay(&front, &cfg, lo_rate, duration_s, seed));
    let (hi_bytes, hi_out) =
        alloc_bytes_during(|| sketched_replay(&front, &cfg, 4.0 * lo_rate, duration_s, seed));
    let req_ratio = hi_out.arrivals as f64 / lo_out.arrivals as f64;
    let byte_ratio = hi_bytes as f64 / lo_bytes.max(1) as f64;
    metrics.push(("alloc_bytes_lo".to_string(), lo_bytes as f64));
    metrics.push(("alloc_bytes_hi".to_string(), hi_bytes as f64));
    metrics.push(("arrivals_lo".to_string(), lo_out.arrivals as f64));
    metrics.push(("arrivals_hi".to_string(), hi_out.arrivals as f64));
    metrics.push(("peak_live_bytes".to_string(), PEAK_LIVE_BYTES.load(Relaxed) as f64));

    let mut t = Table::new(&["case", "arrivals", "events", "alloc bytes"]);
    t.row(&[
        format!("{lo_rate:.0} req/s"),
        lo_out.arrivals.to_string(),
        lo_out.events.to_string(),
        lo_bytes.to_string(),
    ]);
    t.row(&[
        format!("{:.0} req/s", 4.0 * lo_rate),
        hi_out.arrivals.to_string(),
        hi_out.events.to_string(),
        hi_bytes.to_string(),
    ]);
    println!("{}", t.render());
    println!(
        "replay {:.2} M events/s, {:.2} M req/s (target 10 M) | 4x requests -> {byte_ratio:.2}x \
         heap traffic",
        events_per_s / 1e6,
        req_per_s / 1e6
    );

    // Structural claims (these gate; raw throughput does not).
    assert!(out.events >= out.arrivals as u64, "events must count every arrival");
    assert!(
        req_ratio > 3.0,
        "high-rate replay only drew {req_ratio:.2}x the arrivals"
    );
    assert!(
        byte_ratio < 2.0,
        "sketched replay heap traffic grew {byte_ratio:.2}x under {req_ratio:.2}x requests — \
         the O(1)-memory path is allocating per request"
    );

    // -- recorder on vs off: the zero-overhead-when-off claim --------------
    // Every run above went through the monomorphized `NoopRecorder` path,
    // so those numbers ARE the recorder-off rows. Re-run the single-core
    // replay with a live `TraceRecorder` and report what opting in costs:
    // throughput delta plus the heap traffic of the structured event
    // stream (recorder-off must add none).
    let mut traced_events = 0usize;
    let r_on = bench("simcore: sketched replay (recorder on)", 1, iters, 30.0, || {
        let (o, evs) = sketched_replay_traced(&front, &cfg, rate, duration_s, seed);
        assert_eq!(o.arrivals, out.arrivals, "recorder perturbed the replay");
        assert_eq!(o.events, out.events, "recorder perturbed the event count");
        traced_events = evs.len();
    });
    println!("{}", r_on.report());
    let on_req_per_s = out.arrivals as f64 / r_on.mean_s;
    let (off_bytes, _) =
        alloc_bytes_during(|| sketched_replay(&front, &cfg, rate, duration_s, seed));
    let (on_bytes, _) =
        alloc_bytes_during(|| sketched_replay_traced(&front, &cfg, rate, duration_s, seed));
    let alloc_delta = on_bytes.saturating_sub(off_bytes);
    metrics.push(("recorder_off_req_per_s".to_string(), req_per_s));
    metrics.push(("recorder_on_req_per_s".to_string(), on_req_per_s));
    metrics.push(("recorder_overhead_x".to_string(), req_per_s / on_req_per_s));
    metrics.push(("recorder_trace_events".to_string(), traced_events as f64));
    metrics.push(("recorder_alloc_delta_bytes".to_string(), alloc_delta as f64));
    results.push(r_on);

    let mut t = Table::new(&["recorder", "req/s", "alloc bytes", "trace events"]);
    t.row(&[
        "off (noop)".to_string(),
        format!("{:.2} M", req_per_s / 1e6),
        off_bytes.to_string(),
        "0".to_string(),
    ]);
    t.row(&[
        "on (trace)".to_string(),
        format!("{:.2} M", on_req_per_s / 1e6),
        on_bytes.to_string(),
        traced_events.to_string(),
    ]);
    println!("{}", t.render());
    println!(
        "recorder on costs {:.2}x throughput, +{alloc_delta} heap bytes for {traced_events} events",
        req_per_s / on_req_per_s
    );
    // Structural: a live recorder must actually capture the run (at least
    // one event per arrival reaches the trace).
    assert!(
        traced_events >= out.arrivals,
        "trace captured {traced_events} events for {} arrivals",
        out.arrivals
    );

    if let Some(path) = json_path_from_args() {
        write_json_with_metrics(&path, &results, &metrics).expect("write bench JSON");
        println!("wrote {}", path.display());
    }
}
