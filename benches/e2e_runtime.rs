//! Bench: end-to-end PJRT serving latency/throughput for the three
//! execution models on real compiled DeiT-T executables (the runtime
//! analog of Fig. 1 / Fig. 2, measured in wall-clock on this host).
//!
//! Requires `make artifacts`.

use std::sync::Arc;

use ssr::bench::{fmt_s, Table};
use ssr::coordinator::pipeline::{synth_images, PipelineServer, SequentialServer};
use ssr::coordinator::StageAssign;
use ssr::dse::Assignment;
use ssr::plan::ExecutionPlan;
use ssr::runtime::exec::Engine;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let requests = if quick { 4 } else { 12 };

    let dir = ssr::runtime::artifacts_dir(None);
    let engine = Engine::load(&dir)?;
    println!("PJRT engine: {} | warming up executables...\n", engine.platform());

    let mut t = Table::new(&["mode", "requests", "lat p50", "lat p99", "img/s", "eff TOPS"]);

    // sequential batch 1 and 6
    let seq = SequentialServer::new(Arc::clone(&engine), "deit_t", &[1, 6])?;
    for &b in &[1usize, 6] {
        let reqs: Vec<_> = (0..(requests / b).max(2))
            .map(|i| synth_images(b, seq.img_size(), i as u64))
            .collect();
        let _ = seq.serve(b, &reqs[..1])?; // warmup
        let (rep, _) = seq.serve(b, &reqs)?;
        t.row(&[
            format!("sequential b{b}"),
            rep.requests.to_string(),
            fmt_s(rep.latency.p50()),
            fmt_s(rep.latency.p99()),
            format!("{:.2}", rep.throughput_rps()),
            format!("{:.4}", rep.effective_tops()),
        ]);
    }

    for (name, assign) in [
        ("spatial 4-acc", StageAssign::spatial()),
        ("hybrid 2-acc", StageAssign { acc_of: [0, 1, 0, 0] }),
        ("hybrid 3-acc", StageAssign { acc_of: [0, 1, 2, 0] }),
    ] {
        let pipe = PipelineServer::new(Arc::clone(&engine), "deit_t", &assign, 1)?;
        let warm: Vec<_> = (0..2).map(|i| synth_images(1, 224, i)).collect();
        let _ = pipe.serve(warm)?;
        let imgs: Vec<_> = (0..requests).map(|i| synth_images(1, 224, i as u64)).collect();
        let (rep, _) = pipe.serve(imgs)?;
        t.row(&[
            name.to_string(),
            rep.requests.to_string(),
            fmt_s(rep.latency.p50()),
            fmt_s(rep.latency.p99()),
            format!("{:.2}", rep.throughput_rps()),
            format!("{:.4}", rep.effective_tops()),
        ]);
    }

    // Plan-driven 8-class hybrids (DSE -> ExecutionPlan -> serve): designs
    // the 4-stage projection cannot represent. Falls back to the coarsened
    // shim (with a log line) on manifests without class-granular stages.
    let depth = engine.manifest.models["deit_t"].depth;
    for (name, genome) in [
        ("plan 5-acc (attn split)", vec![0, 1, 2, 2, 1, 3, 4, 0]),
        ("plan 8-acc (full spatial)", (0..8).collect::<Vec<_>>()),
    ] {
        let a = Assignment::new(genome);
        let plan = ExecutionPlan::from_depth("deit_t", depth, &a, 1);
        let pipe = PipelineServer::from_plan(Arc::clone(&engine), &plan)?;
        let warm: Vec<_> = (0..2).map(|i| synth_images(1, 224, i)).collect();
        let _ = pipe.serve(warm)?;
        let imgs: Vec<_> = (0..requests).map(|i| synth_images(1, 224, i as u64)).collect();
        let (rep, _) = pipe.serve(imgs)?;
        t.row(&[
            format!("{name} [{} accs]", pipe.plan().nacc),
            rep.requests.to_string(),
            fmt_s(rep.latency.p50()),
            fmt_s(rep.latency.p99()),
            format!("{:.2}", rep.throughput_rps()),
            format!("{:.4}", rep.effective_tops()),
        ]);
    }

    println!("{}", t.render());
    println!("(CPU-PJRT wall-clock: absolute numbers are host-dependent; the\n\
              sequential-vs-pipelined *shape* is the reproduced quantity)");
    Ok(())
}
